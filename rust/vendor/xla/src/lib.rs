//! API-compatible stub for the PJRT `xla` crate.
//!
//! The real crate binds the PJRT C API (libxla_extension); that shared
//! library is not part of the hermetic dependency set, so this stub
//! keeps the `pjrt` cargo feature *compiling* everywhere. Every entry
//! point that would touch PJRT returns [`XlaError::Unavailable`] at
//! runtime with instructions to vendor the real crate; the type and
//! method signatures mirror the subset the `obftf` runtime uses.
//!
//! Replace this package (same path, `rust/vendor/xla`) with the real
//! bindings to light up the `pallas` / `jnp` artifact flavours.

use std::fmt;
use std::path::Path;

/// Stub error: always "PJRT unavailable".
pub enum XlaError {
    /// The operation needs the real PJRT runtime.
    Unavailable(&'static str),
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable(op) => write!(
                f,
                "PJRT unavailable ({op}): the in-tree `xla` stub has no backend; \
                 vendor the real xla crate at rust/vendor/xla to run pallas/jnp artifacts"
            ),
        }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(op: &'static str) -> Result<T> {
    Err(XlaError::Unavailable(op))
}

/// Element types the obftf runtime marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Plain-old-data element types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Host-side tensor value (stub: carries no data).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn ty(&self) -> Result<ElementType> {
        unavailable("Literal::ty")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Array shape (dims in elements).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation built from a proto (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err:?}");
        assert!(msg.contains("PJRT unavailable"));
        assert!(msg.contains("vendor the real xla crate"));
    }

    #[test]
    fn literal_constructors_do_not_panic() {
        let _ = Literal::scalar(1.0f32);
        let _ = Literal::scalar(1i32);
        let _ = Literal::vec1(&[1.0f32, 2.0]);
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0; 8]
        )
        .is_err());
    }
}
