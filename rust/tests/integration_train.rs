//! End-to-end integration: the full Algorithm-1 stack.
//!
//! Runs on the manifest's default flavour — the synthesized native
//! manifest (pure-Rust backend) on a fresh checkout, real AOT
//! artifacts when `make artifacts` has been run.

use obftf::config::TrainConfig;
use obftf::coordinator::Trainer;
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn small_cfg(model: &str, method: Method) -> TrainConfig {
    TrainConfig {
        model: model.to_string(),
        method,
        sampling_ratio: 0.25,
        epochs: 2,
        lr: if model == "linreg" { 0.01 } else { 0.05 },
        n_train: Some(512),
        n_test: Some(256),
        seed: 7,
        eval_every: 1,
        ..Default::default()
    }
}

#[test]
fn mlp_obftf_loss_decreases_end_to_end() {
    let m = manifest();
    let cfg = small_cfg("mlp", Method::Obftf);
    let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.evals.len(), 2);
    let first = report.evals.first().unwrap().loss;
    let last = report.evals.last().unwrap().loss;
    assert!(
        last < first,
        "eval loss should decrease over epochs: {first} -> {last}"
    );
    // accuracy above chance (10 classes) after 2 epochs
    assert!(report.final_eval.metric > 0.15, "metric {}", report.final_eval.metric);
    // budget accounting: realized ratio near the configured 0.25
    assert!((report.realized_ratio - 0.25).abs() < 0.08, "{}", report.realized_ratio);
    assert!(report.saved_fraction > 0.3);
}

#[test]
fn every_method_trains_one_epoch_on_linreg() {
    let m = manifest();
    for method in Method::ALL {
        let mut cfg = small_cfg("linreg", method);
        cfg.epochs = 1;
        let mut t = Trainer::with_manifest(&cfg, &m)
            .unwrap_or_else(|e| panic!("{method}: {e:#}"));
        let report = t.run().unwrap_or_else(|e| panic!("{method}: {e:#}"));
        assert!(report.final_eval.loss.is_finite(), "{method}");
        assert!(report.steps > 0, "{method}");
        assert!(report.backward_examples > 0, "{method}");
        assert!(
            report.backward_examples < report.forward_examples,
            "{method} must subsample"
        );
    }
}

#[test]
fn metrics_csv_written_when_configured() {
    let m = manifest();
    let dir = obftf::testkit::TempDir::new("metrics").unwrap();
    let out = dir.file("steps.csv");
    let mut cfg = small_cfg("linreg", Method::ObftfProx);
    cfg.epochs = 1;
    cfg.metrics_out = Some(out.to_string_lossy().to_string());
    Trainer::with_manifest(&cfg, &m).unwrap().run().unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.starts_with("step,epoch,sel_loss"));
    assert!(text.lines().count() > 1);
    let evals = std::fs::read_to_string(out.with_extension("evals.csv")).unwrap();
    assert!(evals.lines().count() >= 2);
}

#[test]
fn sampling_ratio_one_matches_full_batch_training() {
    let m = manifest();
    // ratio = 1.0 with mink (deterministic, selects everything) must
    // behave like plain mini-batch GD: every example gets a backward.
    let mut cfg = small_cfg("linreg", Method::MinK);
    cfg.sampling_ratio = 1.0;
    cfg.epochs = 1;
    let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.forward_examples, report.backward_examples);
    assert!((report.realized_ratio - 1.0).abs() < 1e-9);
    assert!(report.saved_fraction.abs() < 1e-9);
}

#[test]
fn all_available_flavours_agree_on_linreg() {
    // pallas vs jnp must agree bitwise when both artifact flavours are
    // built; on the native manifest this degenerates to a single run.
    // Flavours the current build cannot execute (artifact flavours
    // without the pjrt feature / real PJRT bindings) are skipped.
    let m = manifest();
    let flavours = m.model("linreg").unwrap().flavours();
    assert!(!flavours.is_empty());
    let mut results: Vec<(String, f64)> = Vec::new();
    for flavour in flavours {
        let mut cfg = small_cfg("linreg", Method::Obftf);
        cfg.flavour = flavour.as_str().to_string();
        cfg.epochs = 1;
        let mut t = match Trainer::with_manifest(&cfg, &m) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping flavour {flavour}: {e:#}");
                continue;
            }
        };
        results.push((flavour.to_string(), t.run().unwrap().final_eval.loss));
    }
    for pair in results.windows(2) {
        assert_eq!(
            pair[0].1, pair[1].1,
            "{} {} vs {} {}",
            pair[0].0, pair[0].1, pair[1].0, pair[1].1
        );
    }
}

#[test]
fn loss_reuse_skips_forward_executions() {
    let m = manifest();
    let mut cfg = small_cfg("mlp", Method::ObftfProx);
    cfg.epochs = 4;
    cfg.reuse_losses = true; // auto max_age = 2 epochs
    let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
    let report = t.run().unwrap();
    let (hits, misses) = t.cache_stats();
    assert!(hits > 0, "cache never hit");
    assert!(misses > 0, "first epoch must miss");
    // with the auto max_age, roughly alternate epochs are served from
    // cache → executed forwards well below logical forwards
    assert!(
        t.budget.forward_executed < t.budget.forward_examples,
        "executed {} !< logical {}",
        t.budget.forward_executed,
        t.budget.forward_examples
    );
    assert!(
        t.budget.forward_executed <= t.budget.forward_examples * 3 / 4,
        "expected ≥25% forwards served from cache (executed {} of {})",
        t.budget.forward_executed,
        t.budget.forward_examples
    );
    // staleness must not break training
    assert!(report.final_eval.metric > 0.15, "metric {}", report.final_eval.metric);
}

#[test]
fn loss_reuse_off_executes_every_forward() {
    let m = manifest();
    let mut cfg = small_cfg("linreg", Method::Uniform);
    cfg.epochs = 2;
    let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
    t.run().unwrap();
    assert_eq!(t.budget.forward_executed, t.budget.forward_examples);
    assert_eq!(t.cache_stats(), (0, 0));
}

#[test]
fn gathered_backward_matches_masked_backward() {
    let m = manifest();
    let run = |masked: bool| {
        let mut cfg = small_cfg("mlp", Method::ObftfProx);
        cfg.epochs = 1;
        cfg.masked_backward = masked;
        let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
        t.run().unwrap().final_eval
    };
    let gathered = run(false);
    let masked = run(true);
    // identical selections (same rng), identical masked-mean objective →
    // numerically equal training trajectories
    assert!(
        (gathered.loss - masked.loss).abs() < 1e-6 * masked.loss.abs().max(1.0),
        "gathered {} vs masked {}",
        gathered.loss,
        masked.loss
    );
    assert!((gathered.metric - masked.metric).abs() < 1e-3);
}

#[test]
fn incompatible_model_dataset_rejected_up_front() {
    let m = manifest();
    let mut cfg = small_cfg("mlp", Method::Uniform);
    cfg.dataset = Some("regression".to_string()); // 1 feature vs 784
    let err = match Trainer::with_manifest(&cfg, &m) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected shape-mismatch error"),
    };
    assert!(err.contains("incompatible"), "err: {err}");
}

#[test]
fn unknown_model_rejected() {
    let m = manifest();
    let cfg = small_cfg("transformer", Method::Uniform);
    assert!(Trainer::with_manifest(&cfg, &m).is_err());
}
