//! Failure injection: the runtime must fail *loudly and early* on
//! corrupted artifacts, broken manifests, and bad checkpoints — and
//! stay usable after recoverable errors.
//!
//! Artifact-corruption tests need real on-disk HLO artifacts plus the
//! `pjrt` feature; they skip otherwise. Everything else runs on the
//! manifest's default flavour (native on a fresh checkout).

use obftf::runtime::{Engine, Manifest, Session};
use obftf::testkit::TempDir;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

/// Artifact-backed tests (`pjrt` feature + built artifacts only).
#[cfg(feature = "pjrt")]
mod artifact_corruption {
    use super::*;
    use obftf::runtime::Flavour;

    fn artifact_manifest() -> Option<Manifest> {
        let dir = obftf::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).expect("manifest loads"))
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    /// Clone the real artifacts dir into a temp dir (symlink-free copy
    /// of just the files one model needs) so we can corrupt things
    /// safely.
    fn clone_artifacts(model: &str) -> Option<(TempDir, Manifest)> {
        let m = artifact_manifest()?;
        let dir = TempDir::new("corrupt").unwrap();
        let entry = m.model(model).unwrap();
        for fname in entry.executables.values() {
            std::fs::copy(m.dir.join(fname), dir.path().join(fname)).unwrap();
        }
        // single-model manifest json
        let text = std::fs::read_to_string(m.dir.join("manifest.json")).unwrap();
        let j = obftf::util::json::parse(&text).unwrap();
        let mut out = obftf::util::json::Json::obj();
        out.set("version", j.need("version").unwrap().clone());
        out.set("batch", j.need("batch").unwrap().clone());
        let mut models = obftf::util::json::Json::obj();
        models.set(model, j.need("models").unwrap().need(model).unwrap().clone());
        out.set("models", models);
        std::fs::write(dir.file("manifest.json"), out.to_string_pretty()).unwrap();
        let cloned = Manifest::load(dir.path()).unwrap();
        Some((dir, cloned))
    }

    #[test]
    fn corrupted_hlo_artifact_fails_compile_with_context() {
        let Some((dir, m)) = clone_artifacts("linreg") else { return };
        let fname = m
            .model("linreg")
            .unwrap()
            .artifact(obftf::runtime::Exe::FwdLoss, Flavour::Jnp)
            .unwrap()
            .to_string();
        std::fs::write(dir.file(&fname), "HloModule garbage\n%%%not hlo%%%").unwrap();
        let err = match Session::new(&m, "linreg", Flavour::Jnp) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("corrupted artifact must not compile"),
        };
        assert!(err.contains("fwd_loss"), "error should name the executable: {err}");
    }

    #[test]
    fn truncated_hlo_artifact_fails() {
        let Some((dir, m)) = clone_artifacts("linreg") else { return };
        let fname = m
            .model("linreg")
            .unwrap()
            .artifact(obftf::runtime::Exe::TrainStep, Flavour::Jnp)
            .unwrap()
            .to_string();
        let full = std::fs::read_to_string(dir.file(&fname)).unwrap();
        std::fs::write(dir.file(&fname), &full[..full.len() / 3]).unwrap();
        assert!(Session::new(&m, "linreg", Flavour::Jnp).is_err());
    }

    #[test]
    fn engine_startup_fails_fast_on_bad_artifacts() {
        let Some((dir, m)) = clone_artifacts("linreg") else { return };
        let fname = m
            .model("linreg")
            .unwrap()
            .artifact(obftf::runtime::Exe::Init, Flavour::Jnp)
            .unwrap()
            .to_string();
        std::fs::write(dir.file(&fname), "not hlo at all").unwrap();
        let err = match Engine::new(&m, "linreg", Flavour::Jnp, 2) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("engine must fail fast"),
        };
        assert!(err.contains("failed to start"), "{err}");
    }
}

#[test]
fn manifest_with_garbage_json_rejected() {
    let dir = TempDir::new("badjson").unwrap();
    std::fs::write(dir.file("manifest.json"), "{ not json !!!").unwrap();
    let err = match Manifest::load(dir.path()) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("garbage manifest must not load"),
    };
    assert!(err.contains("parse"), "{err}");
}

#[test]
fn garbage_manifest_is_not_silently_replaced_by_native() {
    // load_or_native falls back only when NO manifest exists; a broken
    // one must still fail loudly
    let dir = TempDir::new("badjson2").unwrap();
    std::fs::write(dir.file("manifest.json"), "{ not json !!!").unwrap();
    assert!(Manifest::load_or_native(dir.path()).is_err());
}

#[test]
fn manifest_missing_required_keys_rejected() {
    let dir = TempDir::new("badkeys").unwrap();
    std::fs::write(dir.file("manifest.json"), r#"{"version": 1}"#).unwrap();
    let err = match Manifest::load(dir.path()) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!(),
    };
    assert!(err.contains("missing key"), "{err}");
}

#[test]
fn checkpoint_dtype_tag_corruption_detected() {
    use obftf::checkpoint::Checkpoint;
    use obftf::data::HostTensor;
    let dir = TempDir::new("ckcorrupt").unwrap();
    let p = dir.file("x.ck");
    Checkpoint {
        step: 1,
        epoch: 1,
        params: vec![("w".into(), HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap())],
    }
    .save(&p)
    .unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    // flip the dtype tag byte (directly after name + rank + dims)
    let tag_pos = 4 + 4 + 8 + 8 + 4 + (4 + 1) + 4 + 8;
    bytes[tag_pos] = 77;
    std::fs::write(&p, &bytes).unwrap();
    let err = match Checkpoint::load(&p) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("corrupt dtype tag must fail"),
    };
    assert!(err.contains("dtype"), "{err}");
}

#[test]
fn session_survives_a_rejected_request_sequence() {
    let m = manifest();
    use obftf::data::HostTensor;
    let mut s = Session::new(&m, "linreg", m.default_flavour()).unwrap();
    s.init(1).unwrap();
    let n = m.batch;
    let x = HostTensor::f32(vec![n, 1], vec![0.1; n]).unwrap();
    let y = HostTensor::f32(vec![n], vec![0.2; n]).unwrap();
    // storm of invalid calls
    for _ in 0..5 {
        let _ = s.fwd_loss(&y, &x); // swapped shapes
        let _ = s.train_step(&x, &y, &[1.0], 0.1); // bad mask
        let _ = s.apply(&[], 0.1); // bad arity
    }
    // still healthy
    let losses = s.fwd_loss(&x, &y).unwrap();
    assert_eq!(losses.len(), n);
    let mask = vec![1.0f32; n];
    let l = s.train_step(&x, &y, &mask, 0.01).unwrap();
    assert!(l.is_finite());
}

#[test]
fn engine_rejects_mismatched_shard_counts() {
    let m = manifest();
    let engine = Engine::new(&m, "linreg", m.default_flavour(), 2).unwrap();
    engine.init_broadcast(1).unwrap();
    use obftf::data::HostTensor;
    let n = m.batch;
    let x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
    let y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();
    // 1 shard for 2 workers: must be rejected, engine stays usable
    assert!(engine.fwd_loss_sharded(vec![(x.clone(), y.clone())]).is_err());
    let ok = engine
        .fwd_loss_sharded(vec![(x.clone(), y.clone()), (x, y)])
        .unwrap();
    assert_eq!(ok.len(), 2);
}
