//! Failure injection for the multi-process fleet: a worker that dies
//! mid-pipeline must surface as a *fast, contextual* error on the
//! leader — naming the worker id, the child's exit status and the last
//! frame sent to it — never as an indefinite hang. The transport is
//! driven directly (publish → submit → await_losses) so the test pins
//! the fail-fast machinery itself, not the trainer around it.
//!
//! The crash is injected with the worker subcommand's test-only
//! `--fail-after N` flag: the child processes N frames normally, then
//! exits abruptly (status 17, no `Shutdown`/`WorkerStats` handshake) on
//! receiving the next — exactly what a kill -9 mid-step looks like
//! from the leader's side of the pipes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use obftf::coordinator::{FleetSpec, FleetTransport, LinkMode, Transport};
use obftf::data::dataset::{Batch, InMemoryDataset};
use obftf::data::{Rng, Targets};
use obftf::runtime::{Flavour, Manifest, ScorePrecision, Session};

/// restart_limit = 0: these tests pin the strict fail-fast behaviour
/// (the elastic supervised-restart path is pinned in socket_restart.rs).
fn spec(workers: usize, capacity: usize, fail_after: Vec<Option<u64>>) -> FleetSpec {
    FleetSpec {
        model: "linreg".into(),
        flavour: Flavour::Native,
        workers,
        capacity,
        max_age: 0,
        sync: true,
        score_precision: ScorePrecision::F32,
        param_precision: ScorePrecision::F32,
        worker_bin: Some(env!("CARGO_BIN_EXE_obftf").into()),
        timeout: Duration::from_secs(60),
        fail_after,
        link: LinkMode::Pipes,
        affinity: true,
        restart_limit: 0,
        // floor == fleet size: retirement can never kick in, so these
        // tests keep pinning the strict fail-fast surface
        min_workers: workers,
        max_entries: 0,
        overlap: false,
    }
}

/// A linreg-shaped batch covering ids `0..batch` of a synthetic set.
fn fixture() -> (Session, Batch, usize) {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest");
    let batch_size = manifest.batch;
    let capacity = batch_size * 2;
    let mut rng = Rng::seed_from(23);
    let xs: Vec<f32> = (0..capacity).map(|_| rng.normal() as f32).collect();
    let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 0.5).collect();
    let ds = InMemoryDataset::new(vec![1], xs, Targets::F32(ys)).unwrap();
    let ids: Vec<usize> = (0..batch_size).collect();
    let batch = ds.gather_batch(&ids, batch_size).unwrap();
    let mut session = Session::new(&manifest, "linreg", Flavour::Native).unwrap();
    session.init(5).unwrap();
    (session, batch, capacity)
}

/// Happy path: the distributed fleet scores a batch bit-identically to
/// a local session, shard owners record exactly their rows, and the
/// shutdown handshake returns every worker's stats.
#[test]
fn proc_transport_scores_bit_identically_and_reports_stats() {
    let (mut session, batch, capacity) = fixture();
    let expect = session.fwd_loss(&batch.x, &batch.y).unwrap();
    let mut t = FleetTransport::spawn(spec(2, capacity, Vec::new())).expect("fleet spawns");
    assert_eq!(t.n_workers(), 2);
    assert_eq!(t.workers_alive(), 2);
    t.publish(0, &Arc::new(session.snapshot().unwrap())).unwrap();
    let batch = Arc::new(batch);
    t.submit(&batch).unwrap();
    let losses = t.await_losses(&batch, 0).expect("losses arrive");
    assert_eq!(losses.len(), batch.batch_size());
    for (row, (got, want)) in losses.iter().zip(&expect).enumerate() {
        if batch.valid_mask[row] > 0.0 {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "row {row}: cross-process loss must be bit-identical"
            );
        } else {
            assert_eq!(*got, 0.0, "padding rows read as 0.0");
        }
    }
    assert_eq!(
        t.worker_scored(),
        vec![1, 0],
        "affinity tie (ids split evenly across shards) routes to the lowest worker"
    );
    let summary = t.shutdown().expect("clean shutdown");
    assert_eq!(summary.workers.len(), 2);
    assert_eq!(summary.workers_alive, 2);
    assert_eq!(summary.restarts, 0);
    assert_eq!(summary.fleet_rows, batch.real as u64);
    assert!(summary.frame_bytes > 0);
    let w0 = &summary.workers[0];
    let w1 = &summary.workers[1];
    assert_eq!((w0.scored_batches, w1.scored_batches), (1, 0));
    // worker 0 owns the even ids, worker 1 the odd ids (routed rows)
    assert_eq!(w0.recorded_rows + w1.recorded_rows, batch.real as u64);
    assert_eq!(w0.recorded_rows, w1.recorded_rows);
    assert!(w0.lookups >= 1 && w1.lookups >= 1, "both shard owners served views");
}

/// The satellite regression: kill a worker mid-pipeline and the leader
/// must fail fast with worker id + last-frame context instead of
/// blocking until the stall timeout.
#[test]
fn leader_fails_fast_with_context_when_a_worker_dies() {
    let (session, batch, capacity) = fixture();
    // worker 1 survives exactly one frame (the ParamUpdate), then
    // crashes on whatever arrives next
    let mut t =
        FleetTransport::spawn(spec(2, capacity, vec![None, Some(1)])).expect("fleet spawns");
    t.publish(0, &Arc::new(session.snapshot().unwrap())).unwrap();
    let batch = Arc::new(batch);
    t.submit(&batch).unwrap();
    let t0 = Instant::now();
    let err = t.await_losses(&batch, 0).expect_err("dead worker must fail the handoff");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1"), "error must name the dead worker: {msg}");
    assert!(
        msg.contains("last frame sent"),
        "error must carry last-frame context: {msg}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "death must be detected by the reader thread, not the stall timeout ({:?})",
        t0.elapsed()
    );
    assert!(t.workers_alive() < 2, "the dead worker is marked");
}

/// Same injection, end to end: the pipeline trainer itself surfaces the
/// failure instead of hanging or silently degrading.
#[test]
fn pipeline_run_surfaces_worker_death() {
    use obftf::config::TrainConfig;
    use obftf::coordinator::PipelineTrainer;
    use obftf::sampling::Method;
    std::env::set_var("OBFTF_WORKER_BIN", env!("CARGO_BIN_EXE_obftf"));
    // the injection travels by env so the spawn path stays production-
    // shaped; this file runs in its own test process, and the other
    // tests here drive FleetTransport directly with explicit fail_after,
    // so the variable cannot leak anywhere it matters
    std::env::set_var("OBFTF_PROC_FAIL_AFTER", "1:2");
    // zero the restart budget and pin the worker floor to the fleet
    // size: the default elastic policy would respawn (or, with a spent
    // budget and headroom above the floor, retire) the crashed worker
    // and heal the run, but this test pins the fail-fast surface
    std::env::set_var("OBFTF_PIPELINE_RESTART_LIMIT", "0");
    std::env::set_var("OBFTF_PIPELINE_MIN_WORKERS", "2");
    let cfg = TrainConfig {
        model: "linreg".to_string(),
        method: Method::MinK,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: 12,
        lr: 0.01,
        n_train: Some(256),
        n_test: Some(128),
        seed: 7,
        pipeline: true,
        pipeline_proc: true,
        pipeline_sync: true,
        pipeline_workers: 2,
        ..Default::default()
    };
    let mut p = PipelineTrainer::from_config(&cfg).unwrap();
    let err = p.run().expect_err("worker death must fail the run");
    let msg = format!("{err:#}");
    std::env::remove_var("OBFTF_PROC_FAIL_AFTER");
    std::env::remove_var("OBFTF_PIPELINE_RESTART_LIMIT");
    std::env::remove_var("OBFTF_PIPELINE_MIN_WORKERS");
    assert!(msg.contains("worker 1"), "run error must name the worker: {msg}");
}

/// Spawn failures are contextual too: a missing worker binary names the
/// worker and the path instead of dying downstream.
#[test]
fn missing_worker_binary_is_a_contextual_spawn_error() {
    let mut s = spec(1, 64, Vec::new());
    s.worker_bin = Some("/nonexistent/obftf-worker-binary".into());
    let err = FleetTransport::spawn(s).expect_err("spawn must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("spawning pipeline worker 0"), "msg: {msg}");
    assert!(msg.contains("/nonexistent/obftf-worker-binary"), "msg: {msg}");
}
