//! The elastic half of the socket fleet: a worker killed mid-run is
//! *restarted* by the supervised-restart policy instead of failing the
//! job — its replacement re-handshakes, receives the current weights,
//! re-warms its loss-cache shard from the leader's routed-row journal,
//! and the run completes with `worker_restarts > 0` and results that
//! are still bit-identical to the serial oracle (sync mode scores
//! every row under the current parameter version, so a heal can never
//! smuggle in a staleness-bound violation).
//!
//! Two layers are pinned: the transport driven directly (crash →
//! restart → re-warmed lookups), and the pipeline trainer end to end
//! over a Unix-socket fleet with an injected mid-run crash.

use std::sync::Arc;
use std::time::Duration;

use obftf::config::TrainConfig;
use obftf::coordinator::{
    FleetSpec, FleetTransport, LinkMode, PipelineTrainer, StreamingTrainer, Transport,
};
use obftf::data::dataset::{Batch, InMemoryDataset};
use obftf::data::{Rng, Targets, TensorData};
use obftf::runtime::{Flavour, Manifest, ScorePrecision, Session};
use obftf::sampling::Method;

fn spec(workers: usize, capacity: usize, fail_after: Vec<Option<u64>>) -> FleetSpec {
    FleetSpec {
        model: "linreg".into(),
        flavour: Flavour::Native,
        workers,
        capacity,
        max_age: 0,
        sync: true,
        score_precision: ScorePrecision::F32,
        param_precision: ScorePrecision::F32,
        worker_bin: Some(env!("CARGO_BIN_EXE_obftf").into()),
        timeout: Duration::from_secs(60),
        fail_after,
        link: LinkMode::Unix,
        affinity: true,
        restart_limit: 2,
        min_workers: 1,
        max_entries: 0,
        overlap: false,
    }
}

fn fixture() -> (Session, Batch, usize) {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest");
    let batch_size = manifest.batch;
    let capacity = batch_size * 2;
    let mut rng = Rng::seed_from(41);
    let xs: Vec<f32> = (0..capacity).map(|_| rng.normal() as f32).collect();
    let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 0.5).collect();
    let ds = InMemoryDataset::new(vec![1], xs, Targets::F32(ys)).unwrap();
    let ids: Vec<usize> = (0..batch_size).collect();
    let batch = ds.gather_batch(&ids, batch_size).unwrap();
    let mut session = Session::new(&manifest, "linreg", Flavour::Native).unwrap();
    session.init(5).unwrap();
    (session, batch, capacity)
}

/// Transport layer: worker 1 crashes after its second frame (the
/// ParamUpdate plus one more). The supervisor must respawn it, replay
/// its journal, and the very same `await_losses` call must still
/// return losses bit-identical to a local session — with exactly one
/// restart on the books and both shard owners answering lookups.
#[test]
fn socket_worker_crash_is_healed_by_supervised_restart() {
    let (mut session, batch, capacity) = fixture();
    let expect = session.fwd_loss(&batch.x, &batch.y).unwrap();
    let mut t =
        FleetTransport::spawn(spec(2, capacity, vec![None, Some(1)])).expect("fleet spawns");
    t.publish(0, &Arc::new(session.snapshot().unwrap())).unwrap();
    let batch = Arc::new(batch);
    t.submit(&batch).unwrap();
    let losses = t.await_losses(&batch, 0).expect("restart heals the handoff");
    assert_eq!(losses.len(), batch.batch_size());
    for (row, (got, want)) in losses.iter().zip(&expect).enumerate() {
        if batch.valid_mask[row] > 0.0 {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "row {row}: healed fleet must stay bit-identical"
            );
        }
    }
    assert_eq!(t.restarts(), 1, "exactly one supervised restart");
    assert_eq!(t.workers_alive(), 2, "the replacement counts as alive");
    let summary = t.shutdown().expect("clean shutdown");
    assert_eq!(summary.restarts, 1);
    assert_eq!(summary.workers.len(), 2);
    assert_eq!(summary.workers_alive, 2);
    // the re-warmed shard answered: every real row was recorded by a
    // shard owner and both owners served lookups
    let recorded: u64 = summary.workers.iter().map(|w| w.recorded_rows).sum();
    assert_eq!(recorded, batch.real as u64);
    assert!(summary.workers.iter().all(|w| w.lookups >= 1));
}

/// A worker that keeps dying exhausts the restart budget and the
/// leader fails with full context instead of respawning forever.
#[test]
fn restart_budget_exhaustion_fails_with_context() {
    let (session, batch, capacity) = fixture();
    let mut s = spec(1, capacity, vec![Some(0)]);
    s.restart_limit = 0;
    let mut t = FleetTransport::spawn(s).expect("fleet spawns");
    let batch = Arc::new(batch);
    let err = t
        .publish(0, &Arc::new(session.snapshot().unwrap()))
        .and_then(|()| t.submit(&batch))
        .and_then(|()| t.await_losses(&batch, 0).map(|_| ()))
        .expect_err("zero budget must fail fast");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 0"), "error names the worker: {msg}");
}

/// End to end over Unix sockets: serial oracle vs a socket pipeline
/// whose worker 1 is killed mid-run by `--fail-after` injection. The
/// run must complete, record the restart in its step telemetry, and
/// stay bit-for-bit equal to serial — selection hashes, losses and
/// final weights.
#[test]
fn socket_pipeline_survives_midrun_worker_kill_bit_identically() {
    std::env::set_var("OBFTF_WORKER_BIN", env!("CARGO_BIN_EXE_obftf"));
    // worker 1 dies on its 7th frame — a few steps in, mid-pipeline
    std::env::set_var("OBFTF_PROC_FAIL_AFTER", "1:6");
    let m = Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest");
    let base = TrainConfig {
        model: "mlp".to_string(),
        method: Method::Obftf,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: 12,
        lr: 0.05,
        n_train: Some(512),
        n_test: Some(256),
        seed: 31,
        eval_every: 5,
        ..Default::default()
    };
    let mut serial = StreamingTrainer::with_manifest(&base, &m).unwrap();
    serial.run().unwrap();
    let sparams = serial.trainer().session().params_to_host().unwrap();

    let mut pc = base.clone();
    pc.pipeline = true;
    pc.pipeline_sync = true;
    pc.pipeline_proc = true;
    pc.pipeline_socket = "unix".to_string();
    pc.pipeline_workers = 2;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    let report = p.run().expect("restart policy must heal the injected kill");
    std::env::remove_var("OBFTF_PROC_FAIL_AFTER");
    assert_eq!(report.steps, 12);

    // the kill actually happened and was healed, not dodged
    let last = p.recorder.steps.last().expect("steps recorded");
    assert!(last.worker_restarts > 0, "run must have restarted a worker");
    assert_eq!(last.workers_alive, 2, "fleet is whole again at the end");

    // bit-for-bit against serial, restart and all
    let srecs = &serial.trainer().recorder.steps;
    let precs = &p.recorder.steps;
    assert_eq!(srecs.len(), precs.len());
    for (a, b) in srecs.iter().zip(precs.iter()) {
        assert_eq!(a.sel_hash, b.sel_hash, "step {}: selected sets differ", a.step);
        assert_eq!(
            a.sel_loss.to_bits(),
            b.sel_loss.to_bits(),
            "step {} sel_loss diverged across the restart",
            a.step
        );
    }
    let pparams = p.session().params_to_host().unwrap();
    assert_eq!(sparams.len(), pparams.len());
    for (i, (ta, tb)) in sparams.iter().zip(&pparams).enumerate() {
        match (&ta.data, &tb.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                for (j, (x, y)) in va.iter().zip(vb).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "param {i}[{j}] diverged");
                }
            }
            _ => panic!("params must be f32"),
        }
    }
}
