//! The staged pipeline bounded against the serial trainer oracle.
//!
//! With synchronous stage handoffs and staleness forced to zero (every
//! loss scored under the current parameter version), the pipeline must
//! reproduce the serial streaming trainer *bit for bit*: identical
//! selected sets (order included — the gathered backward reduces in
//! selection order), identical per-step losses, identical final
//! weights, identical eval trajectory. This holds for **every**
//! transport: the in-process thread fleet and the multi-process
//! `obftf worker` fleet over pipes, Unix sockets and loopback TCP
//! (the wire codec ships f32 bit-exactly, so crossing a process or
//! socket boundary changes nothing). Async mode is bounded loosely:
//! it must complete, train and account its cache traffic.

use obftf::config::TrainConfig;
use obftf::coordinator::{PipelineTrainer, StreamingTrainer, TrainReport};
use obftf::data::TensorData;
use obftf::runtime::{Manifest, ScorePrecision};
use obftf::sampling::Method;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

/// The proc transport spawns `obftf worker` children; under `cargo
/// test` the current executable is the *test* binary, so point the
/// transport at the real CLI binary cargo built alongside it.
fn use_cli_worker_bin() {
    std::env::set_var("OBFTF_WORKER_BIN", env!("CARGO_BIN_EXE_obftf"));
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".to_string(),
        method: Method::Obftf,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: steps,
        lr: 0.05,
        n_train: Some(512),
        n_test: Some(256),
        seed: 31,
        eval_every: 3,
        prefetch_depth: 3,
        ..Default::default()
    }
}

fn cnn_lite_cfg(steps: usize) -> TrainConfig {
    let mut c = cfg(steps);
    c.model = "cnn_lite".to_string();
    c.dataset = Some("imagenet_proxy".into());
    c.n_train = Some(256);
    c.n_test = Some(128);
    c.lr = 0.1;
    c
}

fn assert_params_bit_identical(a: &[obftf::data::HostTensor], b: &[obftf::data::HostTensor]) {
    assert_eq!(a.len(), b.len());
    for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.shape, tb.shape, "param {i} shape");
        match (&ta.data, &tb.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                for (j, (x, y)) in va.iter().zip(vb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {i}[{j}]: serial {x} vs pipeline {y}"
                    );
                }
            }
            _ => panic!("params must be f32"),
        }
    }
}

/// Run the serial streaming oracle for `base`, then for each fleet
/// size run the sync pipeline over the given transport (`mode` is
/// `"thread"`, `"proc"` for pipes, or `"unix"`/`"tcp"` for sockets)
/// and assert the bit-for-bit contract: selected sets, per-step
/// losses, final weights, eval trajectory, compute accounting.
fn assert_sync_pipeline_equivalent(base: &TrainConfig, worker_counts: &[usize], mode: &str) {
    let m = manifest();
    let mut serial = StreamingTrainer::with_manifest(base, &m).unwrap();
    let sreport = serial.run().unwrap();
    let sparams = serial.trainer().session().params_to_host().unwrap();
    assert_eq!(sreport.steps, base.stream_steps as u64);

    for &workers in worker_counts {
        let tag = mode;
        let mut pc = base.clone();
        pc.pipeline = true;
        pc.pipeline_sync = true;
        match mode {
            "thread" => {}
            "proc" => pc.pipeline_proc = true,
            "unix" | "tcp" => {
                pc.pipeline_proc = true;
                pc.pipeline_socket = mode.to_string();
            }
            other => panic!("unknown transport mode {other:?}"),
        }
        pc.pipeline_workers = workers;
        let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
        let preport = p.run().unwrap();
        assert_eq!(preport.steps, sreport.steps, "{tag} workers={workers}");

        // bit-identical selected sets and per-step losses
        let srecs = &serial.trainer().recorder.steps;
        let precs = &p.recorder.steps;
        assert_eq!(srecs.len(), precs.len());
        for (a, b) in srecs.iter().zip(precs.iter()) {
            assert_eq!(
                a.sel_hash, b.sel_hash,
                "{tag} workers={workers} step {}: selected sets differ",
                a.step
            );
            assert_eq!(a.n_selected, b.n_selected, "step {}", a.step);
            assert_eq!(
                a.sel_loss.to_bits(),
                b.sel_loss.to_bits(),
                "{tag} workers={workers} step {} sel_loss: {} vs {}",
                a.step,
                a.sel_loss,
                b.sel_loss
            );
            assert_eq!(
                a.batch_loss.to_bits(),
                b.batch_loss.to_bits(),
                "{tag} workers={workers} step {} batch_loss",
                a.step
            );
            // the fleet is alive for every recorded step
            assert_eq!(b.workers_alive as usize, workers, "step {}", a.step);
            assert_eq!(b.worker_restarts, 0, "step {}", a.step);
        }

        // bit-identical final weights
        let pparams = p.session().params_to_host().unwrap();
        assert_params_bit_identical(&sparams, &pparams);

        // same async-eval cadence, same values
        assert_eq!(sreport.evals.len(), preport.evals.len());
        assert!(!preport.evals.is_empty(), "eval cadence must have fired");
        for (a, b) in sreport.evals.iter().zip(&preport.evals) {
            assert_eq!(a.step, b.step);
            assert!(
                (a.loss - b.loss).abs() <= 1e-12 * a.loss.abs().max(1.0),
                "eval at step {}: {} vs {}",
                a.step,
                a.loss,
                b.loss
            );
            assert!((a.metric - b.metric).abs() <= 1e-12);
        }

        // same compute accounting
        assert_eq!(preport.forward_examples, sreport.forward_examples);
        assert_eq!(preport.backward_examples, sreport.backward_examples);
        assert_fleet_accounting(&p, &preport, workers, mode != "thread");
    }
}

/// Transport-level bookkeeping the sync contract also pins: every
/// stream batch was scored exactly once (sync mode never requeues),
/// and the fleet transports actually moved frames.
fn assert_fleet_accounting(p: &PipelineTrainer, report: &TrainReport, workers: usize, fleet: bool) {
    let stats = p.worker_stats();
    assert_eq!(stats.len(), workers);
    let scored: u64 = stats.iter().map(|w| w.scored_batches).sum();
    assert_eq!(scored, report.steps, "one scoring per step in sync mode");
    assert_eq!(p.budget.inference_forwards, report.forward_examples);
    if fleet {
        // distributed ownership: every scored row was recorded by
        // exactly one shard owner
        let recorded: u64 = stats.iter().map(|w| w.recorded_rows).sum();
        assert_eq!(recorded, p.budget.inference_forwards);
        assert!(p.frame_bytes() > 0, "fleet transport must move frames");
    } else {
        assert_eq!(p.frame_bytes(), 0, "thread transport is wire-free");
    }
}

/// The acceptance pin: sync thread pipeline ≡ serial trainer on the
/// mlp manifest, at 1 and 3 inference workers.
#[test]
fn sync_pipeline_is_bit_identical_to_serial_streaming() {
    let mut base = cfg(12);
    base.cache_shards = 3;
    assert_sync_pipeline_equivalent(&base, &[1, 3], "thread");
}

/// The same bit-for-bit pin on the conv workload: the staged pipeline
/// must reproduce the serial streaming trainer exactly on cnn_lite
/// (native conv chain), so all six sampling methods and the pipeline
/// run Table 3's scenario unchanged.
#[test]
fn sync_pipeline_is_bit_identical_to_serial_streaming_on_cnn_lite() {
    assert_sync_pipeline_equivalent(&cnn_lite_cfg(6), &[1, 2], "thread");
}

/// The multi-process acceptance pin: sync **proc** pipeline — `obftf
/// worker` children, losses crossing stdin/stdout as typed frames,
/// distributed shard ownership — is still bit-identical to the serial
/// trainer at 1 and 2 worker processes.
#[test]
fn sync_proc_pipeline_is_bit_identical_to_serial_streaming() {
    use_cli_worker_bin();
    assert_sync_pipeline_equivalent(&cfg(8), &[1, 2], "proc");
}

/// The socket-fleet acceptance pin: the same `obftf worker` children
/// reached over **Unix-domain sockets** — `OBFTF_LISTEN` bootstrap,
/// `Hello` handshake, frames over the stream — stay bit-identical to
/// the serial trainer at 1 and 2 worker processes.
#[test]
fn sync_unix_socket_pipeline_is_bit_identical_to_serial_streaming() {
    use_cli_worker_bin();
    assert_sync_pipeline_equivalent(&cfg(8), &[1, 2], "unix");
}

/// And over **loopback TCP**: connect_timeout + TCP_NODELAY on both
/// halves, identical frames, identical bits.
#[test]
fn sync_tcp_socket_pipeline_is_bit_identical_to_serial_streaming() {
    use_cli_worker_bin();
    assert_sync_pipeline_equivalent(&cfg(6), &[2], "tcp");
}

/// And the conv workload across the process boundary: NHWC batches and
/// conv weights ship bit-exactly, so cnn_lite proc runs match serial
/// bit for bit at 1 and 2 worker processes.
#[test]
fn sync_proc_pipeline_is_bit_identical_on_cnn_lite() {
    use_cli_worker_bin();
    assert_sync_pipeline_equivalent(&cnn_lite_cfg(4), &[1, 2], "proc");
}

#[test]
fn async_pipeline_trains_and_accounts_cache_traffic() {
    let m = manifest();
    let mut pc = cfg(30);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_workers = 3;
    pc.pipeline_depth = 4;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    let report = p.run().unwrap();
    assert_eq!(report.steps, 30);
    assert!(report.final_eval.loss.is_finite());
    assert!(!report.evals.is_empty(), "async eval must have recorded");
    // exactly one counting lookup per step
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 30);
    // the fleet scored every issued batch (requeues only add to this)
    assert!(p.budget.inference_forwards >= 30 * m.batch as u64);
    // per-shard row counters saw the traffic
    let shards = p.options().shards;
    let row_lookups: u64 = (0..shards)
        .map(|k| {
            let s = p.shard_stats(k);
            s.hits + s.misses
        })
        .sum();
    assert!(row_lookups > 0);
    assert!(report.realized_ratio > 0.0);
}

/// Async mode over the proc transport: same loose bounds as the thread
/// fleet — completes, trains, counts one counting lookup per step and
/// attributes row traffic to the owning workers.
#[test]
fn async_proc_pipeline_trains_and_accounts_cache_traffic() {
    use_cli_worker_bin();
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_proc = true;
    pc.pipeline_workers = 2;
    pc.pipeline_depth = 3;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    assert!(p.options().transport.is_fleet());
    assert_eq!(p.options().shards, 2, "fleet mode: one shard set per worker");
    let report = p.run().unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite());
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 20);
    assert!(p.budget.inference_forwards >= 20 * m.batch as u64);
    let row_lookups: u64 = (0..2)
        .map(|k| {
            let s = p.shard_stats(k);
            s.hits + s.misses
        })
        .sum();
    assert!(row_lookups > 0, "row traffic must be attributed to owners");
    assert!(p.frame_bytes() > 0);
}

/// bf16 fast-scoring in the async pipeline: the fleet scores in bf16
/// (relaxed tolerance), the leader still selects a valid subset each
/// step and the budget accounting stays coherent — one counting lookup
/// per step, every issued batch scored, and a per-step backward count
/// that tracks the configured sampling ratio.
#[test]
fn async_bf16_scoring_pipeline_selects_and_accounts() {
    let m = manifest();
    let mut pc = cfg(30);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_workers = 3;
    pc.pipeline_depth = 4;
    pc.score_precision = "bf16".into();
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    assert_eq!(p.options().score_precision, ScorePrecision::Bf16);
    let report = p.run().unwrap();
    assert_eq!(report.steps, 30);
    assert!(report.final_eval.loss.is_finite(), "eval runs exact f32 and must stay finite");
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 30);
    assert!(p.budget.inference_forwards >= 30 * m.batch as u64);
    // the selected subset tracks the configured ratio (0.25 of the
    // batch): bf16 perturbs *which* rows win, never how many
    let per_step = report.backward_examples as f64 / report.steps as f64;
    let want = pc.sampling_ratio * m.batch as f64;
    assert!(
        (per_step - want).abs() <= want * 0.5,
        "selected {per_step}/step, expected ~{want}"
    );
    assert!(report.realized_ratio > 0.0);
}

/// bf16 *param broadcast* in the async proc pipeline: the leader ships
/// half-size `ParamUpdate` frames, workers expand to f32 on receipt,
/// and the run still selects with coherent accounting — one counting
/// lookup per step, every issued batch scored, eval (leader-side,
/// exact f32) finite, and per-step telemetry carrying the broadcast
/// byte counts the knob is supposed to shrink.
#[test]
fn async_bf16_param_broadcast_pipeline_selects_and_accounts() {
    use_cli_worker_bin();
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_proc = true;
    pc.pipeline_workers = 2;
    pc.pipeline_depth = 3;
    pc.param_precision = "bf16".into();
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    assert_eq!(p.options().param_precision, ScorePrecision::Bf16);
    assert_eq!(p.options().score_precision, ScorePrecision::F32, "knobs are independent");
    let report = p.run().unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite(), "leader eval is exact f32");
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 20);
    assert!(p.budget.inference_forwards >= 20 * m.batch as u64);
    // wire telemetry: frames moved, and the param split is populated
    let wire = p.wire_stats();
    assert!(wire.frames > 0, "leader must have sent frames");
    assert!(wire.param_bytes > 0, "broadcast bytes must be accounted");
    let last = p.recorder.steps.last().expect("steps recorded");
    assert!(last.frames_per_step > 0, "per-step frame telemetry populated");
    assert!(last.publish_bytes > 0, "per-step broadcast bytes populated");
    // the selected subset still tracks the configured ratio: a bf16
    // weight broadcast perturbs scores, never the budget
    let per_step = report.backward_examples as f64 / report.steps as f64;
    let want = pc.sampling_ratio * m.batch as f64;
    assert!(
        (per_step - want).abs() <= want * 0.5,
        "selected {per_step}/step, expected ~{want}"
    );
}

/// Sync mode must refuse a bf16 param broadcast for the same reason it
/// refuses bf16 scoring: the oracle contract is bit-identity.
#[test]
fn sync_pipeline_rejects_bf16_param_broadcast() {
    let m = manifest();
    let mut pc = cfg(6);
    pc.pipeline = true;
    pc.pipeline_sync = true;
    pc.param_precision = "bf16".into();
    let err =
        PipelineTrainer::with_manifest(&pc, &m).err().expect("sync + bf16 must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("param_precision"), "error must name the knob: {msg}");
    assert!(msg.contains("pipeline_sync"), "error must name the conflict: {msg}");
}

/// Sync mode is the bit-identical oracle — it must refuse to score in
/// bf16 rather than silently weaken the equivalence contract.
#[test]
fn sync_pipeline_rejects_bf16_scoring() {
    let m = manifest();
    let mut pc = cfg(6);
    pc.pipeline = true;
    pc.pipeline_sync = true;
    pc.score_precision = "bf16".into();
    let err = PipelineTrainer::with_manifest(&pc, &m).err().expect("sync + bf16 must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("pipeline_sync"), "error must name the conflict: {msg}");
}

#[test]
fn bounded_staleness_requeues_and_completes() {
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_workers = 2;
    // lookahead deliberately deeper than the staleness bound so the
    // re-score path must engage for the run to finish
    pc.pipeline_depth = 6;
    pc.loss_max_age = 1;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    let report = p.run().unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite());
}

#[test]
fn pipeline_requires_streaming_mode() {
    let m = manifest();
    let mut pc = cfg(0);
    pc.epochs = 1; // valid config overall, but not for the pipeline ctor
    pc.pipeline = false; // validate() would reject pipeline+no-stream
    assert!(PipelineTrainer::with_manifest(&pc, &m).is_err());
}

/// Sync mode must refuse the overlapped leader outright: prefetch,
/// parallel publish fan-out and the recorder stage all reorder work
/// around the serial lookup → select → backward → publish schedule
/// that *is* the oracle's contract.
#[test]
fn sync_pipeline_rejects_overlap() {
    let m = manifest();
    let mut pc = cfg(6);
    pc.pipeline = true;
    pc.pipeline_sync = true;
    pc.pipeline_overlap = true;
    let err =
        PipelineTrainer::with_manifest(&pc, &m).err().expect("sync + overlap must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("pipeline_overlap"), "error must name the knob: {msg}");
    assert!(msg.contains("pipeline_sync"), "error must name the conflict: {msg}");
}

/// The overlap machinery compiled in but resolved *off* (config asks,
/// the CLI override declines) must leave the sync socket fleet exactly
/// where it was: bit-identical to the serial trainer at 1 and 2 worker
/// processes. This pins that the overlap plumbing — spec field, writer
/// scaffolding, prefetch hooks, epilogue struct — is genuinely inert
/// unless the knob resolves on.
#[test]
fn sync_socket_pipeline_with_overlap_declined_stays_bit_identical() {
    use_cli_worker_bin();
    let mut base = cfg(8);
    base.pipeline = true;
    base.pipeline_overlap = true;
    base.overrides.overlap = Some(false);
    assert_sync_pipeline_equivalent(&base, &[1, 2], "unix");
}

/// The overlapped leader under staleness pressure: lookahead deeper
/// than `loss_max_age`, so prefetched views classified at *use* time
/// must land in the requeue path for the run to finish. The counting
/// contract survives — prefetch moves *when* the counting lookup runs,
/// never how often — so hits + misses still equals steps exactly.
#[test]
fn async_overlap_pipeline_respects_staleness_bound() {
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_overlap = true;
    pc.pipeline_workers = 2;
    pc.pipeline_depth = 6;
    pc.loss_max_age = 1;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    assert!(p.options().overlap);
    let report = p.run().unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite());
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 20, "one counting lookup per step, overlap or not");
    assert!(p.budget.inference_forwards >= 20 * m.batch as u64);
}

/// The overlapped leader over the socket fleet: prefetched lookups
/// cross the wire under the leader's backward, the per-endpoint writer
/// threads carry the broadcast, and the run still trains with coherent
/// accounting. The lookup round trip is measured issue-to-merge, so
/// the per-step telemetry column must be populated.
#[test]
fn async_overlap_socket_pipeline_trains_with_prefetch_telemetry() {
    use_cli_worker_bin();
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_proc = true;
    pc.pipeline_socket = "unix".into();
    pc.pipeline_overlap = true;
    pc.pipeline_workers = 2;
    pc.pipeline_depth = 3;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    assert!(p.options().overlap);
    assert!(p.options().transport.is_fleet());
    let report = p.run().unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite());
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 20);
    assert!(p.budget.inference_forwards >= 20 * m.batch as u64);
    assert!(p.frame_bytes() > 0);
    assert!(
        p.recorder.steps.iter().any(|r| r.lookup_rtt_us > 0),
        "issue-to-merge lookup RTT must reach the per-step telemetry"
    );
}

/// Transport-level crash injection under an in-flight prefetch: worker
/// 1 survives exactly the `ParamUpdate`, then dies on the prefetched
/// `CacheLookup` fan-out. The supervised restart bumps the epoch, the
/// parked prefetch is voided (never collected against the wrong
/// incarnation), and `await_losses` re-issues against the healed fleet
/// — journal re-warm included, so the routed rows the dead incarnation
/// lost still answer bit-identically.
#[test]
fn worker_death_mid_prefetch_retries_against_the_healed_fleet() {
    use std::sync::Arc;
    use std::time::Duration;

    use obftf::coordinator::{FleetSpec, FleetTransport, LinkMode, Transport};
    use obftf::data::dataset::{Batch, InMemoryDataset};
    use obftf::data::{Rng, Targets};
    use obftf::runtime::{Flavour, Session};

    let m = manifest();
    let batch_size = m.batch;
    let capacity = batch_size * 2;
    let mut rng = Rng::seed_from(47);
    let xs: Vec<f32> = (0..capacity).map(|_| rng.normal() as f32).collect();
    let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 0.5).collect();
    let ds = InMemoryDataset::new(vec![1], xs, Targets::F32(ys)).unwrap();
    let ids: Vec<usize> = (0..batch_size).collect();
    let batch: Arc<Batch> = Arc::new(ds.gather_batch(&ids, batch_size).unwrap());
    let mut session = Session::new(&m, "linreg", Flavour::Native).unwrap();
    session.init(5).unwrap();
    let expect = session.fwd_loss(&batch.x, &batch.y).unwrap();

    let spec = FleetSpec {
        model: "linreg".into(),
        flavour: Flavour::Native,
        workers: 2,
        capacity,
        max_age: 4,
        sync: false,
        score_precision: ScorePrecision::F32,
        param_precision: ScorePrecision::F32,
        worker_bin: Some(env!("CARGO_BIN_EXE_obftf").into()),
        timeout: Duration::from_secs(60),
        // worker 1 handles the ParamUpdate, then crashes on the next
        // frame — which the prefetch below puts on the wire
        fail_after: vec![None, Some(1)],
        link: LinkMode::Unix,
        affinity: true,
        restart_limit: 2,
        min_workers: 1,
        max_entries: 0,
        overlap: true,
    };
    let mut t = FleetTransport::spawn(spec).expect("fleet spawns");
    t.publish(0, &Arc::new(session.snapshot().unwrap())).unwrap();
    t.submit(&batch).unwrap();
    t.prefetch(&batch, 0).expect("prefetch issues");
    let losses = t.await_losses(&batch, 0).expect("losses arrive after the restart");
    for (row, (got, want)) in losses.iter().zip(&expect).enumerate() {
        if batch.valid_mask[row] > 0.0 {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "row {row}: healed fleet must still score bit-identically"
            );
        }
    }
    assert_eq!(t.restarts(), 1, "exactly one supervised restart");
    assert_eq!(t.workers_alive(), 2, "the crashed worker was respawned");
    assert!(t.lookup_rtt_us() > 0, "the collected lookup stamps its RTT");
    let summary = t.shutdown().expect("clean shutdown");
    assert_eq!(summary.restarts, 1);
    assert_eq!(summary.workers_alive, 2);
}
