//! The staged pipeline bounded against the serial trainer oracle.
//!
//! With synchronous stage handoffs and staleness forced to zero (every
//! loss scored under the current parameter version), the pipeline must
//! reproduce the serial streaming trainer *bit for bit*: identical
//! selected sets (order included — the gathered backward reduces in
//! selection order), identical per-step losses, identical final
//! weights, identical eval trajectory. Async mode is bounded loosely:
//! it must complete, train and account its cache traffic.

use obftf::config::TrainConfig;
use obftf::coordinator::{PipelineTrainer, StreamingTrainer};
use obftf::data::TensorData;
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".to_string(),
        method: Method::Obftf,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: steps,
        lr: 0.05,
        n_train: Some(512),
        n_test: Some(256),
        seed: 31,
        eval_every: 3,
        prefetch_depth: 3,
        ..Default::default()
    }
}

fn assert_params_bit_identical(a: &[obftf::data::HostTensor], b: &[obftf::data::HostTensor]) {
    assert_eq!(a.len(), b.len());
    for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.shape, tb.shape, "param {i} shape");
        match (&ta.data, &tb.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                for (j, (x, y)) in va.iter().zip(vb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {i}[{j}]: serial {x} vs pipeline {y}"
                    );
                }
            }
            _ => panic!("params must be f32"),
        }
    }
}

/// The acceptance pin: sync pipeline ≡ serial trainer on the mlp
/// manifest, at 1 and 3 inference workers.
#[test]
fn sync_pipeline_is_bit_identical_to_serial_streaming() {
    let m = manifest();
    let c = cfg(12);
    let mut serial = StreamingTrainer::with_manifest(&c, &m).unwrap();
    let sreport = serial.run().unwrap();
    let sparams = serial.trainer().session().params_to_host().unwrap();
    assert_eq!(sreport.steps, 12);

    for workers in [1usize, 3] {
        let mut pc = c.clone();
        pc.pipeline = true;
        pc.pipeline_sync = true;
        pc.pipeline_workers = workers;
        pc.cache_shards = 3;
        let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
        let preport = p.run().unwrap();
        assert_eq!(preport.steps, sreport.steps, "workers={workers}");

        // bit-identical selected sets and per-step losses
        let srecs = &serial.trainer().recorder.steps;
        let precs = &p.recorder.steps;
        assert_eq!(srecs.len(), precs.len());
        for (a, b) in srecs.iter().zip(precs.iter()) {
            assert_eq!(
                a.sel_hash, b.sel_hash,
                "workers={workers} step {}: selected sets differ",
                a.step
            );
            assert_eq!(a.n_selected, b.n_selected, "step {}", a.step);
            assert_eq!(
                a.sel_loss.to_bits(),
                b.sel_loss.to_bits(),
                "workers={workers} step {} sel_loss: {} vs {}",
                a.step,
                a.sel_loss,
                b.sel_loss
            );
            assert_eq!(
                a.batch_loss.to_bits(),
                b.batch_loss.to_bits(),
                "workers={workers} step {} batch_loss",
                a.step
            );
        }

        // bit-identical final weights
        let pparams = p.session().params_to_host().unwrap();
        assert_params_bit_identical(&sparams, &pparams);

        // same async-eval cadence, same values
        assert_eq!(sreport.evals.len(), preport.evals.len());
        assert!(!preport.evals.is_empty(), "eval cadence must have fired");
        for (a, b) in sreport.evals.iter().zip(&preport.evals) {
            assert_eq!(a.step, b.step);
            assert!(
                (a.loss - b.loss).abs() <= 1e-12 * a.loss.abs().max(1.0),
                "eval at step {}: {} vs {}",
                a.step,
                a.loss,
                b.loss
            );
            assert!((a.metric - b.metric).abs() <= 1e-12);
        }

        // same compute accounting
        assert_eq!(preport.forward_examples, sreport.forward_examples);
        assert_eq!(preport.backward_examples, sreport.backward_examples);
    }
}

/// The same bit-for-bit pin on the conv workload: the staged pipeline
/// must reproduce the serial streaming trainer exactly on cnn_lite
/// (native conv chain), so all six sampling methods and the pipeline
/// run Table 3's scenario unchanged.
#[test]
fn sync_pipeline_is_bit_identical_to_serial_streaming_on_cnn_lite() {
    let m = manifest();
    let mut c = cfg(6);
    c.model = "cnn_lite".to_string();
    c.dataset = Some("imagenet_proxy".into());
    c.n_train = Some(256);
    c.n_test = Some(128);
    c.lr = 0.1;
    let mut serial = StreamingTrainer::with_manifest(&c, &m).unwrap();
    let sreport = serial.run().unwrap();
    let sparams = serial.trainer().session().params_to_host().unwrap();
    assert_eq!(sreport.steps, 6);

    for workers in [1usize, 2] {
        let mut pc = c.clone();
        pc.pipeline = true;
        pc.pipeline_sync = true;
        pc.pipeline_workers = workers;
        let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
        let preport = p.run().unwrap();
        assert_eq!(preport.steps, sreport.steps, "workers={workers}");

        let srecs = &serial.trainer().recorder.steps;
        let precs = &p.recorder.steps;
        assert_eq!(srecs.len(), precs.len());
        for (a, b) in srecs.iter().zip(precs.iter()) {
            assert_eq!(
                a.sel_hash, b.sel_hash,
                "workers={workers} step {}: selected sets differ",
                a.step
            );
            assert_eq!(
                a.sel_loss.to_bits(),
                b.sel_loss.to_bits(),
                "workers={workers} step {} sel_loss: {} vs {}",
                a.step,
                a.sel_loss,
                b.sel_loss
            );
        }
        let pparams = p.session().params_to_host().unwrap();
        assert_params_bit_identical(&sparams, &pparams);
        assert_eq!(preport.forward_examples, sreport.forward_examples);
        assert_eq!(preport.backward_examples, sreport.backward_examples);
    }
}

#[test]
fn async_pipeline_trains_and_accounts_cache_traffic() {
    let m = manifest();
    let mut pc = cfg(30);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_workers = 3;
    pc.pipeline_depth = 4;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    let report = p.run().unwrap();
    assert_eq!(report.steps, 30);
    assert!(report.final_eval.loss.is_finite());
    assert!(!report.evals.is_empty(), "async eval must have recorded");
    // exactly one counting lookup per step
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 30);
    // the fleet scored every issued batch (requeues only add to this)
    assert!(p.budget.inference_forwards >= 30 * m.batch as u64);
    // per-shard row counters saw the traffic
    let shards = p.knobs().shards;
    let row_lookups: u64 = (0..shards)
        .map(|k| {
            let s = p.shard_stats(k);
            s.hits + s.misses
        })
        .sum();
    assert!(row_lookups > 0);
    assert!(report.realized_ratio > 0.0);
}

#[test]
fn bounded_staleness_requeues_and_completes() {
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_workers = 2;
    // lookahead deliberately deeper than the staleness bound so the
    // re-score path must engage for the run to finish
    pc.pipeline_depth = 6;
    pc.loss_max_age = 1;
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    let report = p.run().unwrap();
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite());
}

#[test]
fn pipeline_requires_streaming_mode() {
    let m = manifest();
    let mut pc = cfg(0);
    pc.epochs = 1; // valid config overall, but not for the pipeline ctor
    pc.pipeline = false; // validate() would reject pipeline+no-stream
    assert!(PipelineTrainer::with_manifest(&pc, &m).is_err());
}
