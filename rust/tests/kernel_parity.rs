//! Property tests for the blocked kernel subsystem
//! (`runtime/kernels/`): the blocked, threaded kernels must match the
//! naive `kernels/reference.rs` oracle across awkward shapes, be
//! bit-identical across thread counts, and preserve the
//! gathered-vs-masked bit-equality invariant of the native backend.
//!
//! Inputs come from the shared [`obftf::testkit::cases`] vocabulary (the
//! conv mirror of this file is `tests/conv_parity.rs`).

use obftf::data::rng::Rng;
use obftf::data::{HostTensor, TensorData};
use obftf::runtime::kernels::{self, reference, Arena};
use obftf::runtime::{Backend, KernelConfig, Manifest, NativeBackend};
use obftf::testkit::cases::{
    check_close, class_batch, dense_dims, normal_vec, relu_vec, zero_rows_except_period,
};
use obftf::testkit::{propcheck, TempDir};

const REL_TOL: f32 = 1e-4;

/// One randomized kernel-parity case: shapes deliberately straddle the
/// register-tile sizes (`MR`/`NR`), and the data is regenerated from
/// `data_seed` so failures print a compact, replayable description.
#[derive(Debug)]
struct Case {
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
    relu: bool,
    mask_period: usize,
    data_seed: u64,
}

fn gen_case(rng: &mut Rng) -> Case {
    let (n, din, dout) = dense_dims(rng);
    Case {
        n,
        din,
        dout,
        threads: 1 + rng.below(5),
        relu: rng.below(2) == 1,
        // every `mask_period`-th dz row is kept, the rest zeroed
        // (mask_period == 0 ⇒ all rows zeroed: the all-masked-out batch)
        mask_period: rng.below(4),
        data_seed: rng.next_u64(),
    }
}

#[test]
fn blocked_kernels_match_reference_on_random_shapes() {
    propcheck("blocked-vs-reference", 60, gen_case, |c| {
        let &Case { n, din, dout, threads, relu, mask_period, data_seed } = c;
        let mut rng = Rng::seed_from(data_seed);
        let h = normal_vec(&mut rng, n * din);
        let w = normal_vec(&mut rng, din * dout);
        let b = normal_vec(&mut rng, dout);
        // ReLU-like activations (exact zeros) for the backward inputs
        let hact = relu_vec(&mut rng, n * din);
        let mut dz = normal_vec(&mut rng, n * dout);
        // masked-out rows carry exact-zero head grads
        zero_rows_except_period(&mut dz, dout, mask_period);

        let cfg = KernelConfig::blocked(threads);
        let mut arena = Arena::new();

        let mut got = vec![0.0f32; n * dout];
        let mut want = vec![0.0f32; n * dout];
        kernels::matmul_bias_act(&cfg, &mut arena, &h, &w, &b, &mut got, n, din, dout, relu);
        reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, relu);
        check_close(&got, &want, REL_TOL, "forward")?;

        let (mut gw, mut gb) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        let (mut ww, mut wb) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        kernels::grad_weights(&cfg, &mut arena, &hact, &dz, &mut gw, &mut gb, n, din, dout);
        reference::grad_weights(&hact, &dz, &mut ww, &mut wb, n, din, dout);
        check_close(&gw, &ww, REL_TOL, "grad_weights")?;
        check_close(&gb, &wb, REL_TOL, "grad_bias")?;

        let mut gh = vec![0.0f32; n * din];
        let mut wh = vec![0.0f32; n * din];
        kernels::grad_input(&cfg, &mut arena, &dz, &w, &hact, &mut gh, n, din, dout);
        reference::grad_input(&dz, &w, &hact, &mut wh, n, din, dout);
        check_close(&gh, &wh, REL_TOL, "grad_input")?;

        // the ungated product must equal the oracle's too
        let mut gu = vec![0.0f32; n * din];
        let mut wu = vec![0.0f32; n * din];
        kernels::matmul_dz_wt(&cfg, &mut arena, &dz, &w, &mut gu, n, din, dout);
        reference::dz_wt(&dz, &w, &mut wu, n, din, dout);
        check_close(&gu, &wu, REL_TOL, "dz_wt")?;
        Ok(())
    });
}

#[test]
fn blocked_kernels_are_thread_count_invariant_bitwise() {
    propcheck("threaded-vs-serial", 40, gen_case, |c| {
        let &Case { n, din, dout, relu, data_seed, .. } = c;
        let mut rng = Rng::seed_from(data_seed);
        let h = normal_vec(&mut rng, n * din);
        let w = normal_vec(&mut rng, din * dout);
        let b = normal_vec(&mut rng, dout);
        let dz = normal_vec(&mut rng, n * dout);
        let mut arena = Arena::new();
        let serial = KernelConfig::blocked(1);
        let threaded = KernelConfig::blocked(4);

        let (mut o1, mut o4) = (vec![0.0f32; n * dout], vec![0.0f32; n * dout]);
        kernels::matmul_bias_act(&serial, &mut arena, &h, &w, &b, &mut o1, n, din, dout, relu);
        kernels::matmul_bias_act(&threaded, &mut arena, &h, &w, &b, &mut o4, n, din, dout, relu);
        if o1 != o4 {
            return Err("forward differs across thread counts".into());
        }
        let (mut w1, mut b1) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        let (mut w4, mut b4) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        kernels::grad_weights(&serial, &mut arena, &h, &dz, &mut w1, &mut b1, n, din, dout);
        kernels::grad_weights(&threaded, &mut arena, &h, &dz, &mut w4, &mut b4, n, din, dout);
        if w1 != w4 || b1 != b4 {
            return Err("grad_weights differs across thread counts".into());
        }
        let (mut h1, mut h4) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
        kernels::grad_input(&serial, &mut arena, &dz, &w, &h, &mut h1, n, din, dout);
        kernels::grad_input(&threaded, &mut arena, &dz, &w, &h, &mut h4, n, din, dout);
        if h1 != h4 {
            return Err("grad_input differs across thread counts".into());
        }
        Ok(())
    });
}

/// The corner shapes the blocking logic must not mishandle, pinned
/// explicitly in addition to the randomized sweep: single row, single
/// input feature, tile-aligned, off-by-one around `MR`/`NR`.
#[test]
fn pinned_awkward_shapes_match_reference() {
    use obftf::runtime::kernels::{MR, NR};
    let shapes = [
        (1, 1, 1),
        (1, NR, NR),
        (MR, NR, NR),
        (MR + 1, NR + 1, NR - 1),
        (2 * MR + 3, 2 * NR + 1, 2 * NR - 1),
        (3, 1, 2 * NR + 5),
        (128, 7, 10),
    ];
    for (n, din, dout) in shapes {
        for threads in [1, 3] {
            let mut rng = Rng::seed_from((n * 1000 + din * 10 + dout) as u64);
            let h = normal_vec(&mut rng, n * din);
            let w = normal_vec(&mut rng, din * dout);
            let b = normal_vec(&mut rng, dout);
            let cfg = KernelConfig::blocked(threads);
            let mut arena = Arena::new();
            let mut got = vec![0.0f32; n * dout];
            let mut want = vec![0.0f32; n * dout];
            kernels::matmul_bias_act(&cfg, &mut arena, &h, &w, &b, &mut got, n, din, dout, true);
            reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, true);
            check_close(&got, &want, REL_TOL, &format!("fwd {n}x{din}x{dout} t{threads}"))
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

/// An all-masked-out batch (every dz row exactly zero) must produce
/// exactly-zero weight gradients on both paths.
#[test]
fn all_masked_out_batch_yields_zero_grads() {
    let (n, din, dout) = (9, 13, 7);
    let mut rng = Rng::seed_from(5);
    let h = normal_vec(&mut rng, n * din);
    let w = normal_vec(&mut rng, din * dout);
    let dz = vec![0.0f32; n * dout];
    for threads in [1, 4] {
        let cfg = KernelConfig::blocked(threads);
        let mut arena = Arena::new();
        let (mut dwv, mut dbv) = (vec![1.0f32; din * dout], vec![1.0f32; dout]);
        kernels::grad_weights(&cfg, &mut arena, &h, &dz, &mut dwv, &mut dbv, n, din, dout);
        assert!(dwv.iter().all(|&v| v == 0.0), "dW must be exactly zero");
        assert!(dbv.iter().all(|&v| v == 0.0), "db must be exactly zero");
        let mut dh = vec![1.0f32; n * din];
        kernels::grad_input(&cfg, &mut arena, &dz, &w, &h, &mut dh, n, din, dout);
        assert!(dh.iter().all(|&v| v == 0.0), "dh must be exactly zero");
    }
}

/// The backend-level invariant the paper's gathered backward relies
/// on: at the real mlp shape (784-256-256-10, batch 128, head width
/// not a multiple of `NR`), the gathered sub-batch step stays
/// bit-identical to the masked full-batch step — with threading
/// disabled *and* enabled — and the parameters themselves are
/// bit-identical across thread counts.
#[test]
fn gathered_step_bit_identical_to_masked_step_threaded_and_serial() {
    let dir = TempDir::new("kparity").unwrap();
    let manifest = Manifest::native(dir.path());
    let entry = manifest.model("mlp").unwrap();
    let n = manifest.batch;
    let (din, classes) = (entry.x_shape[0], entry.num_classes);
    let (x, y) = class_batch(n, din, classes, 71);
    // scattered, unsorted selection across the batch
    let selected: Vec<usize> = vec![97, 3, 40, 41, 42, 11, 127, 64, 5, 80];
    let mut mask = vec![0.0f32; n];
    for &i in &selected {
        mask[i] = 1.0;
    }

    let mut end_params: Vec<Vec<HostTensor>> = vec![];
    for threads in [1usize, 4] {
        let cfg = KernelConfig::blocked(threads);
        let mut masked = NativeBackend::with_kernel_config("mlp", entry, n, cfg).unwrap();
        let mut gathered = NativeBackend::with_kernel_config("mlp", entry, n, cfg).unwrap();
        masked.init(9).unwrap();
        gathered.init(9).unwrap();
        for step in 0..2 {
            let lm = masked.train_step(&x, &y, &mask, 0.05).unwrap();
            let lg = gathered.train_step_selected(&x, &y, &selected, 0.05).unwrap();
            assert_eq!(lm, lg, "t{threads} step {step}: masked {lm} vs gathered {lg}");
        }
        let pm = masked.params_to_host().unwrap();
        let pg = gathered.params_to_host().unwrap();
        for (a, b) in pm.iter().zip(&pg) {
            match (&a.data, &b.data) {
                (TensorData::F32(va), TensorData::F32(vb)) => {
                    assert_eq!(va, vb, "t{threads}: masked vs gathered params")
                }
                _ => panic!("params must be f32"),
            }
        }
        end_params.push(pm);
    }
    for (a, b) in end_params[0].iter().zip(&end_params[1]) {
        match (&a.data, &b.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                assert_eq!(va, vb, "params must be thread-count invariant")
            }
            _ => panic!("params must be f32"),
        }
    }
}
