//! Property tests for the blocked kernel subsystem
//! (`runtime/kernels/`): the blocked, threaded kernels must match the
//! naive `kernels/reference.rs` oracle across awkward shapes, be
//! bit-identical across thread counts, and preserve the
//! gathered-vs-masked bit-equality invariant of the native backend.
//! The AVX2 register-tile kernels are held to a stricter bar — *bitwise*
//! equal to the blocked path on every f32 training kernel — while the
//! bf16 scoring forward gets a relaxed tolerance pinned here too.
//!
//! Inputs come from the shared [`obftf::testkit::cases`] vocabulary (the
//! conv mirror of this file is `tests/conv_parity.rs`).

use obftf::data::rng::Rng;
use obftf::data::{HostTensor, TensorData};
use obftf::runtime::kernels::{self, reference, simd_available, Arena};
use obftf::runtime::{Backend, KernelConfig, Manifest, NativeBackend, ScorePrecision};
use obftf::testkit::cases::{
    check_close, class_batch, dense_dims, normal_vec, relu_vec, zero_rows_except_period,
};
use obftf::testkit::{propcheck, TempDir};

const REL_TOL: f32 = 1e-4;

/// Error bound of the bf16 scoring forward relative to exact f32: a
/// bf16 mantissa keeps 8 significant bits (~2⁻⁸ ≈ 4e-3 per rounding)
/// and products accumulate in f32, so 1e-2 relative holds with margin
/// at the paper's layer widths. Documented in the README contract.
const BF16_REL_TOL: f32 = 1e-2;

/// One randomized kernel-parity case: shapes deliberately straddle the
/// register-tile sizes (`MR`/`NR`), and the data is regenerated from
/// `data_seed` so failures print a compact, replayable description.
#[derive(Debug)]
struct Case {
    n: usize,
    din: usize,
    dout: usize,
    threads: usize,
    relu: bool,
    mask_period: usize,
    data_seed: u64,
}

fn gen_case(rng: &mut Rng) -> Case {
    let (n, din, dout) = dense_dims(rng);
    Case {
        n,
        din,
        dout,
        threads: 1 + rng.below(5),
        relu: rng.below(2) == 1,
        // every `mask_period`-th dz row is kept, the rest zeroed
        // (mask_period == 0 ⇒ all rows zeroed: the all-masked-out batch)
        mask_period: rng.below(4),
        data_seed: rng.next_u64(),
    }
}

#[test]
fn blocked_kernels_match_reference_on_random_shapes() {
    propcheck("blocked-vs-reference", 60, gen_case, |c| {
        let &Case { n, din, dout, threads, relu, mask_period, data_seed } = c;
        let mut rng = Rng::seed_from(data_seed);
        let h = normal_vec(&mut rng, n * din);
        let w = normal_vec(&mut rng, din * dout);
        let b = normal_vec(&mut rng, dout);
        // ReLU-like activations (exact zeros) for the backward inputs
        let hact = relu_vec(&mut rng, n * din);
        let mut dz = normal_vec(&mut rng, n * dout);
        // masked-out rows carry exact-zero head grads
        zero_rows_except_period(&mut dz, dout, mask_period);

        let cfg = KernelConfig::blocked(threads);
        let mut arena = Arena::new();

        let mut got = vec![0.0f32; n * dout];
        let mut want = vec![0.0f32; n * dout];
        kernels::matmul_bias_act(&cfg, &mut arena, &h, &w, &b, &mut got, n, din, dout, relu);
        reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, relu);
        check_close(&got, &want, REL_TOL, "forward")?;

        let (mut gw, mut gb) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        let (mut ww, mut wb) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        kernels::grad_weights(&cfg, &mut arena, &hact, &dz, &mut gw, &mut gb, n, din, dout);
        reference::grad_weights(&hact, &dz, &mut ww, &mut wb, n, din, dout);
        check_close(&gw, &ww, REL_TOL, "grad_weights")?;
        check_close(&gb, &wb, REL_TOL, "grad_bias")?;

        let mut gh = vec![0.0f32; n * din];
        let mut wh = vec![0.0f32; n * din];
        kernels::grad_input(&cfg, &mut arena, &dz, &w, &hact, &mut gh, n, din, dout);
        reference::grad_input(&dz, &w, &hact, &mut wh, n, din, dout);
        check_close(&gh, &wh, REL_TOL, "grad_input")?;

        // the ungated product must equal the oracle's too
        let mut gu = vec![0.0f32; n * din];
        let mut wu = vec![0.0f32; n * din];
        kernels::matmul_dz_wt(&cfg, &mut arena, &dz, &w, &mut gu, n, din, dout);
        reference::dz_wt(&dz, &w, &mut wu, n, din, dout);
        check_close(&gu, &wu, REL_TOL, "dz_wt")?;
        Ok(())
    });
}

#[test]
fn blocked_kernels_are_thread_count_invariant_bitwise() {
    propcheck("threaded-vs-serial", 40, gen_case, |c| {
        let &Case { n, din, dout, relu, data_seed, .. } = c;
        let mut rng = Rng::seed_from(data_seed);
        let h = normal_vec(&mut rng, n * din);
        let w = normal_vec(&mut rng, din * dout);
        let b = normal_vec(&mut rng, dout);
        let dz = normal_vec(&mut rng, n * dout);
        let mut arena = Arena::new();
        let serial = KernelConfig::blocked(1);
        let threaded = KernelConfig::blocked(4);

        let (mut o1, mut o4) = (vec![0.0f32; n * dout], vec![0.0f32; n * dout]);
        kernels::matmul_bias_act(&serial, &mut arena, &h, &w, &b, &mut o1, n, din, dout, relu);
        kernels::matmul_bias_act(&threaded, &mut arena, &h, &w, &b, &mut o4, n, din, dout, relu);
        if o1 != o4 {
            return Err("forward differs across thread counts".into());
        }
        let (mut w1, mut b1) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        let (mut w4, mut b4) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        kernels::grad_weights(&serial, &mut arena, &h, &dz, &mut w1, &mut b1, n, din, dout);
        kernels::grad_weights(&threaded, &mut arena, &h, &dz, &mut w4, &mut b4, n, din, dout);
        if w1 != w4 || b1 != b4 {
            return Err("grad_weights differs across thread counts".into());
        }
        let (mut h1, mut h4) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
        kernels::grad_input(&serial, &mut arena, &dz, &w, &h, &mut h1, n, din, dout);
        kernels::grad_input(&threaded, &mut arena, &dz, &w, &h, &mut h4, n, din, dout);
        if h1 != h4 {
            return Err("grad_input differs across thread counts".into());
        }
        Ok(())
    });
}

/// The corner shapes the blocking logic must not mishandle, pinned
/// explicitly in addition to the randomized sweep: single row, single
/// input feature, tile-aligned, off-by-one around `MR`/`NR`.
#[test]
fn pinned_awkward_shapes_match_reference() {
    use obftf::runtime::kernels::{MR, NR};
    let shapes = [
        (1, 1, 1),
        (1, NR, NR),
        (MR, NR, NR),
        (MR + 1, NR + 1, NR - 1),
        (2 * MR + 3, 2 * NR + 1, 2 * NR - 1),
        (3, 1, 2 * NR + 5),
        (128, 7, 10),
    ];
    for (n, din, dout) in shapes {
        for threads in [1, 3] {
            let mut rng = Rng::seed_from((n * 1000 + din * 10 + dout) as u64);
            let h = normal_vec(&mut rng, n * din);
            let w = normal_vec(&mut rng, din * dout);
            let b = normal_vec(&mut rng, dout);
            let cfg = KernelConfig::blocked(threads);
            let mut arena = Arena::new();
            let mut got = vec![0.0f32; n * dout];
            let mut want = vec![0.0f32; n * dout];
            kernels::matmul_bias_act(&cfg, &mut arena, &h, &w, &b, &mut got, n, din, dout, true);
            reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, true);
            check_close(&got, &want, REL_TOL, &format!("fwd {n}x{din}x{dout} t{threads}"))
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

/// An all-masked-out batch (every dz row exactly zero) must produce
/// exactly-zero weight gradients on both paths.
#[test]
fn all_masked_out_batch_yields_zero_grads() {
    let (n, din, dout) = (9, 13, 7);
    let mut rng = Rng::seed_from(5);
    let h = normal_vec(&mut rng, n * din);
    let w = normal_vec(&mut rng, din * dout);
    let dz = vec![0.0f32; n * dout];
    for threads in [1, 4] {
        let cfg = KernelConfig::blocked(threads);
        let mut arena = Arena::new();
        let (mut dwv, mut dbv) = (vec![1.0f32; din * dout], vec![1.0f32; dout]);
        kernels::grad_weights(&cfg, &mut arena, &h, &dz, &mut dwv, &mut dbv, n, din, dout);
        assert!(dwv.iter().all(|&v| v == 0.0), "dW must be exactly zero");
        assert!(dbv.iter().all(|&v| v == 0.0), "db must be exactly zero");
        let mut dh = vec![1.0f32; n * din];
        kernels::grad_input(&cfg, &mut arena, &dz, &w, &h, &mut dh, n, din, dout);
        assert!(dh.iter().all(|&v| v == 0.0), "dh must be exactly zero");
    }
}

/// The backend-level invariant the paper's gathered backward relies
/// on: at the real mlp shape (784-256-256-10, batch 128, head width
/// not a multiple of `NR`), the gathered sub-batch step stays
/// bit-identical to the masked full-batch step — with threading
/// disabled *and* enabled — and the parameters themselves are
/// bit-identical across thread counts.
#[test]
fn gathered_step_bit_identical_to_masked_step_threaded_and_serial() {
    let dir = TempDir::new("kparity").unwrap();
    let manifest = Manifest::native(dir.path());
    let entry = manifest.model("mlp").unwrap();
    let n = manifest.batch;
    let (din, classes) = (entry.x_shape[0], entry.num_classes);
    let (x, y) = class_batch(n, din, classes, 71);
    // scattered, unsorted selection across the batch
    let selected: Vec<usize> = vec![97, 3, 40, 41, 42, 11, 127, 64, 5, 80];
    let mut mask = vec![0.0f32; n];
    for &i in &selected {
        mask[i] = 1.0;
    }

    let mut end_params: Vec<Vec<HostTensor>> = vec![];
    for threads in [1usize, 4] {
        let cfg = KernelConfig::blocked(threads);
        let mut masked = NativeBackend::with_kernel_config("mlp", entry, n, cfg).unwrap();
        let mut gathered = NativeBackend::with_kernel_config("mlp", entry, n, cfg).unwrap();
        masked.init(9).unwrap();
        gathered.init(9).unwrap();
        for step in 0..2 {
            let lm = masked.train_step(&x, &y, &mask, 0.05).unwrap();
            let lg = gathered.train_step_selected(&x, &y, &selected, 0.05).unwrap();
            assert_eq!(lm, lg, "t{threads} step {step}: masked {lm} vs gathered {lg}");
        }
        let pm = masked.params_to_host().unwrap();
        let pg = gathered.params_to_host().unwrap();
        for (a, b) in pm.iter().zip(&pg) {
            match (&a.data, &b.data) {
                (TensorData::F32(va), TensorData::F32(vb)) => {
                    assert_eq!(va, vb, "t{threads}: masked vs gathered params")
                }
                _ => panic!("params must be f32"),
            }
        }
        end_params.push(pm);
    }
    for (a, b) in end_params[0].iter().zip(&end_params[1]) {
        match (&a.data, &b.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                assert_eq!(va, vb, "params must be thread-count invariant")
            }
            _ => panic!("params must be f32"),
        }
    }
}

/// The SIMD training kernels are not "close to" the blocked path —
/// they are the *same arithmetic* in 8-wide lanes (`mul`+`add`, no
/// FMA) and must agree bitwise on every f32 kernel. Randomized over
/// the same case vocabulary as the oracle sweep, masked rows included.
/// On a non-AVX2 host the simd flavour dispatches to the blocked path,
/// so the property degrades to a tautology rather than a skip.
#[test]
fn simd_kernels_bitwise_equal_to_blocked() {
    if !simd_available() {
        eprintln!("note: avx2+fma not detected; simd flavour == blocked fallback here");
    }
    propcheck("simd-vs-blocked", 60, gen_case, |c| {
        let &Case { n, din, dout, threads, relu, mask_period, data_seed } = c;
        let mut rng = Rng::seed_from(data_seed);
        let h = normal_vec(&mut rng, n * din);
        let w = normal_vec(&mut rng, din * dout);
        let b = normal_vec(&mut rng, dout);
        let hact = relu_vec(&mut rng, n * din);
        let mut dz = normal_vec(&mut rng, n * dout);
        zero_rows_except_period(&mut dz, dout, mask_period);

        let blocked = KernelConfig::blocked(threads);
        let simd = KernelConfig::simd(threads);
        let mut arena = Arena::new();

        let (mut ob, mut os) = (vec![0.0f32; n * dout], vec![0.0f32; n * dout]);
        kernels::matmul_bias_act(&blocked, &mut arena, &h, &w, &b, &mut ob, n, din, dout, relu);
        kernels::matmul_bias_act(&simd, &mut arena, &h, &w, &b, &mut os, n, din, dout, relu);
        if ob != os {
            return Err("forward: simd differs from blocked bitwise".into());
        }

        let (mut wb2, mut bb) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        let (mut ws, mut bs) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
        kernels::grad_weights(&blocked, &mut arena, &hact, &dz, &mut wb2, &mut bb, n, din, dout);
        kernels::grad_weights(&simd, &mut arena, &hact, &dz, &mut ws, &mut bs, n, din, dout);
        if wb2 != ws || bb != bs {
            return Err("grad_weights: simd differs from blocked bitwise".into());
        }

        let (mut hb, mut hs) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
        kernels::grad_input(&blocked, &mut arena, &dz, &w, &hact, &mut hb, n, din, dout);
        kernels::grad_input(&simd, &mut arena, &dz, &w, &hact, &mut hs, n, din, dout);
        if hb != hs {
            return Err("grad_input: simd differs from blocked bitwise".into());
        }

        let (mut ub, mut us) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
        kernels::matmul_dz_wt(&blocked, &mut arena, &dz, &w, &mut ub, n, din, dout);
        kernels::matmul_dz_wt(&simd, &mut arena, &dz, &w, &mut us, n, din, dout);
        if ub != us {
            return Err("dz_wt: simd differs from blocked bitwise".into());
        }
        Ok(())
    });
}

/// Same corner shapes as the blocked pin, held to the bitwise bar:
/// single row (a 1-high tile), single input feature (k-loop of one),
/// exact tile multiples, off-by-one around `MR`/`NR`, and the
/// all-masked-out batch (zero dz ⇒ exactly-zero grads on the simd
/// path too).
#[test]
fn simd_pinned_awkward_shapes_bitwise_equal_to_blocked() {
    use obftf::runtime::kernels::{MR, NR};
    let shapes = [
        (1, 1, 1),
        (1, NR, NR),
        (MR, NR, NR),
        (MR + 1, NR + 1, NR - 1),
        (2 * MR + 3, 2 * NR + 1, 2 * NR - 1),
        (3, 1, 2 * NR + 5),
        (128, 7, 10),
    ];
    for (n, din, dout) in shapes {
        for threads in [1, 3] {
            let mut rng = Rng::seed_from((n * 1000 + din * 10 + dout) as u64);
            let h = normal_vec(&mut rng, n * din);
            let w = normal_vec(&mut rng, din * dout);
            let b = normal_vec(&mut rng, dout);
            let dz = normal_vec(&mut rng, n * dout);
            let blocked = KernelConfig::blocked(threads);
            let simd = KernelConfig::simd(threads);
            let mut arena = Arena::new();
            let tag = format!("{n}x{din}x{dout} t{threads}");

            let (mut ob, mut os) = (vec![0.0f32; n * dout], vec![0.0f32; n * dout]);
            kernels::matmul_bias_act(&blocked, &mut arena, &h, &w, &b, &mut ob, n, din, dout, true);
            kernels::matmul_bias_act(&simd, &mut arena, &h, &w, &b, &mut os, n, din, dout, true);
            assert_eq!(ob, os, "fwd {tag}: simd must be bitwise-equal to blocked");

            let (mut wb, mut bb) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
            let (mut ws, mut bs) = (vec![0.0f32; din * dout], vec![0.0f32; dout]);
            kernels::grad_weights(&blocked, &mut arena, &h, &dz, &mut wb, &mut bb, n, din, dout);
            kernels::grad_weights(&simd, &mut arena, &h, &dz, &mut ws, &mut bs, n, din, dout);
            assert_eq!(wb, ws, "grad_w {tag}: simd must be bitwise-equal to blocked");
            assert_eq!(bb, bs, "grad_b {tag}: simd must be bitwise-equal to blocked");

            let (mut hb, mut hs) = (vec![0.0f32; n * din], vec![0.0f32; n * din]);
            kernels::grad_input(&blocked, &mut arena, &dz, &w, &h, &mut hb, n, din, dout);
            kernels::grad_input(&simd, &mut arena, &dz, &w, &h, &mut hs, n, din, dout);
            assert_eq!(hb, hs, "grad_in {tag}: simd must be bitwise-equal to blocked");
        }
    }

    // all-masked-out batch under the simd flavour: exact zeros, not tiny
    let (n, din, dout) = (9, 13, 7);
    let mut rng = Rng::seed_from(5);
    let h = normal_vec(&mut rng, n * din);
    let w = normal_vec(&mut rng, din * dout);
    let dz = vec![0.0f32; n * dout];
    let cfg = KernelConfig::simd(3);
    let mut arena = Arena::new();
    let (mut dwv, mut dbv) = (vec![1.0f32; din * dout], vec![1.0f32; dout]);
    kernels::grad_weights(&cfg, &mut arena, &h, &dz, &mut dwv, &mut dbv, n, din, dout);
    assert!(dwv.iter().all(|&v| v == 0.0), "simd dW must be exactly zero");
    assert!(dbv.iter().all(|&v| v == 0.0), "simd db must be exactly zero");
    let mut dh = vec![1.0f32; n * din];
    kernels::grad_input(&cfg, &mut arena, &dz, &w, &h, &mut dh, n, din, dout);
    assert!(dh.iter().all(|&v| v == 0.0), "simd dh must be exactly zero");
}

/// The relaxed contract of the bf16 *scoring* forward: within
/// `BF16_REL_TOL` of the exact-f32 reference on awkward shapes, and
/// with `bf16 = false` the scored entry point is the exact path
/// (bitwise) — so a fleet configured for f32 scoring loses nothing.
/// Inputs carry network-realistic scales (activations ~0.3, fan-in
/// scaled weights) — the regime the contract is stated for; raw
/// unit-normal weights at large `din` concentrate rounding error past
/// any fixed relative bound through cancellation.
#[test]
fn bf16_scored_forward_tracks_reference_within_relaxed_tolerance() {
    use obftf::runtime::kernels::{MR, NR};
    let shapes = [(1, 1, 1), (1, NR, NR), (MR + 1, NR + 1, NR - 1), (64, 100, 33)];
    for (n, din, dout) in shapes {
        for relu in [false, true] {
            let mut rng = Rng::seed_from((n * 7919 + din * 31 + dout) as u64);
            let scale = 1.0 / (din as f32).sqrt();
            let h: Vec<f32> = normal_vec(&mut rng, n * din).iter().map(|v| v * 0.3).collect();
            let w: Vec<f32> = normal_vec(&mut rng, din * dout).iter().map(|v| v * scale).collect();
            let b = normal_vec(&mut rng, dout);
            let cfg = KernelConfig::simd(2);
            let mut arena = Arena::new();
            let tag = format!("{n}x{din}x{dout} relu={relu}");

            let mut want = vec![0.0f32; n * dout];
            reference::matmul_bias_act(&h, &w, &b, &mut want, n, din, dout, relu);

            let mut got = vec![0.0f32; n * dout];
            kernels::matmul_bias_act_scored(
                &cfg, &mut arena, &h, &w, &b, &mut got, n, din, dout, relu, true,
            );
            check_close(&got, &want, BF16_REL_TOL, &format!("bf16 {tag}"))
                .unwrap_or_else(|e| panic!("{e}"));

            let mut exact = vec![0.0f32; n * dout];
            kernels::matmul_bias_act_scored(
                &cfg, &mut arena, &h, &w, &b, &mut exact, n, din, dout, relu, false,
            );
            let mut plain = vec![0.0f32; n * dout];
            kernels::matmul_bias_act(&cfg, &mut arena, &h, &w, &b, &mut plain, n, din, dout, relu);
            assert_eq!(exact, plain, "scored(bf16=false) {tag} must be the exact path");
        }
    }
}

/// A non-finite input must surface as a non-finite score, never a
/// silently-clamped finite one — the selector treats non-finite losses
/// as a poisoned batch and the bf16 rounding must not launder them.
/// Checked at the kernel level (an `inf` activation poisons exactly
/// the rows it touches) and end-to-end through `fwd_loss` on a
/// bf16-scoring backend.
#[test]
fn bf16_scoring_propagates_non_finite_values() {
    let (n, din, dout) = (6, 19, 11);
    let mut rng = Rng::seed_from(13);
    let mut h = normal_vec(&mut rng, n * din);
    let w = normal_vec(&mut rng, din * dout);
    let b = normal_vec(&mut rng, dout);
    h[2 * din + 3] = f32::INFINITY; // poison row 2 only
    let cfg = KernelConfig::simd(1);
    let mut arena = Arena::new();
    let mut out = vec![0.0f32; n * dout];
    kernels::matmul_bias_act_scored(
        &cfg, &mut arena, &h, &w, &b, &mut out, n, din, dout, false, true,
    );
    for row in 0..n {
        let finite = out[row * dout..(row + 1) * dout].iter().all(|v| v.is_finite());
        assert_eq!(finite, row != 2, "bf16 row {row}: only the poisoned row may be non-finite");
    }

    // end to end: an inf feature makes that row's *loss* non-finite
    let dir = TempDir::new("bf16-nonfinite").unwrap();
    let manifest = Manifest::native(dir.path());
    let entry = manifest.model("mlp").unwrap();
    let n = manifest.batch;
    let (mut x, y) = class_batch(n, entry.x_shape[0], entry.num_classes, 29);
    if let TensorData::F32(v) = &mut x.data {
        v[5 * entry.x_shape[0]] = f32::INFINITY; // poison row 5
    }
    let mut backend =
        NativeBackend::with_kernel_config("mlp", entry, n, KernelConfig::simd(1)).unwrap();
    backend.init(3).unwrap();
    backend.set_score_precision(ScorePrecision::Bf16);
    let losses = backend.fwd_loss(&x, &y).unwrap();
    assert!(!losses[5].is_finite(), "poisoned row's bf16 loss must stay non-finite");
    assert!(losses[0].is_finite(), "clean rows must stay finite");
}
