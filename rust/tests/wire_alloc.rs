//! Allocation-count regression for the pooled wire path.
//!
//! The zero-allocation contract: once a connection's scratch buffers
//! are warm, the *wire path* — frame encoding (borrowed encoders, the
//! coalescing envelope, the pre-encoded param broadcast), the framing
//! layer, and pooled decode through [`proto::FramePools`] — performs
//! zero heap allocations per frame. The formerly documented exception
//! (decode-side payload materialization, named in ROADMAP.md as the
//! PR-8 residual) is closed: a warm pool hands recycled `ids`/`losses`/
//! `rows` vectors back to the decoder, so a nonempty pooled decode
//! costs nothing. The unpooled `read_frame_into` fallback still pays
//! exactly one allocation per payload vector; both counts are pinned
//! here, so a regression in either direction (new hidden allocations,
//! or an encoder growing a buffer it should reuse) fails loudly.
//!
//! The counter is a test-only counting global allocator with a
//! per-thread tally (tests in one binary run on separate threads, so
//! parallel tests cannot disturb each other's counts).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::Cursor;

use obftf::coordinator::proto::{
    self, EnvelopeEncoder, Frame, FramePools, ViewRow, WorkerStats, NO_ID, PROTO_VERSION,
};
use obftf::data::HostTensor;
use obftf::runtime::ScorePrecision;

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = Cell::new(0);
}

fn bump() {
    // try_with: the TLS slot may already be torn down during thread
    // exit, and an allocator must never panic
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Heap allocations (alloc + realloc) on this thread during `f`.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.with(|c| c.get());
    f();
    ALLOCS.with(|c| c.get()) - before
}

/// Every steady-state leader/worker encode — score replies, view
/// replies, lookup fan-outs, the coalescing envelope, the param
/// broadcast at both precisions, and `Frame::encode_into` on a reused
/// frame — must allocate nothing once its scratch buffer is warm.
#[test]
fn warm_encoders_allocate_nothing() {
    let ids: Vec<u64> = (0..64).collect();
    let losses: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
    let rows: Vec<ViewRow> =
        (0..64).map(|i| ViewRow { pos: i, loss: i as f32, stamp: 3 }).collect();
    let weights = vec![
        HostTensor::f32(vec![8, 4], (0..32).map(|i| i as f32).collect()).unwrap(),
        HostTensor::f32(vec![4], vec![0.5, -1.5, f32::NAN, 2.0]).unwrap(),
    ];
    let shutdown = Frame::Shutdown;
    let stats = Frame::WorkerStats(WorkerStats {
        worker: 1,
        scored_batches: 10,
        scored_rows: 640,
        recorded_rows: 320,
        lookups: 10,
    });
    let mut buf = Vec::new();
    let encode_all = |buf: &mut Vec<u8>| {
        proto::encode_loss_records_into(7, 1, 5, &ids, &losses, buf);
        proto::encode_cache_view_into(9, 1, &rows, buf);
        proto::encode_cache_lookup_into(9, 5, true, &ids, buf);
        proto::encode_param_update_into(5, &weights, ScorePrecision::F32, buf);
        proto::encode_param_update_into(5, &weights, ScorePrecision::Bf16, buf);
        proto::encode_reshard_into(2, &ids, buf);
        proto::encode_shard_transfer_into(2, 1, &ids, &losses, &ids, buf);
        let mut env = EnvelopeEncoder::begin(buf);
        env.member_loss_records(u64::MAX, 0, 4, &ids, &losses);
        env.member_loss_records(u64::MAX, 1, 4, &ids, &losses);
        env.member_cache_lookup(9, 5, true, &ids);
        env.finish();
        shutdown.encode_into(buf);
        stats.encode_into(buf);
    };
    encode_all(&mut buf); // warm the scratch buffer
    let n = allocs_during(|| {
        for _ in 0..3 {
            encode_all(&mut buf);
        }
    });
    assert_eq!(n, 0, "warm wire-path encodes must not allocate ({n} allocations)");
}

/// The framing layer of `read_frame_into` with a warm body buffer
/// allocates nothing; frames whose decoded payloads are empty (or
/// payload-free) round the whole read down to zero allocations.
#[test]
fn warm_read_frame_into_framing_allocates_nothing() {
    let mut wire = Vec::new();
    wire.extend_from_slice(&Frame::Hello { proto: PROTO_VERSION, worker: 0 }.encode());
    wire.extend_from_slice(&Frame::Shutdown.encode());
    wire.extend_from_slice(&Frame::WorkerStats(WorkerStats::default()).encode());
    wire.extend_from_slice(
        &Frame::LossRecords { seq: 1, worker: 0, stamp: 2, ids: vec![], losses: vec![] }
            .encode(),
    );
    wire.extend_from_slice(
        &Frame::CacheLookup { req: 3, now: 4, exact: true, ids: vec![] }.encode(),
    );
    let mut body = Vec::new();
    // warm pass: body grows to the connection's largest frame
    let mut cur = Cursor::new(wire.as_slice());
    let mut frames = 0;
    while proto::read_frame_into(&mut cur, &mut body).unwrap().is_some() {
        frames += 1;
    }
    assert_eq!(frames, 5);
    // steady state: replay the same stream — zero allocations
    let mut cur = Cursor::new(wire.as_slice());
    let n = allocs_during(|| {
        while proto::read_frame_into(&mut cur, &mut body).unwrap().is_some() {}
    });
    assert_eq!(n, 0, "warm framing + empty-payload decodes must not allocate ({n})");
}

/// The PR-8 residual, closed: with a warm [`FramePools`], nonempty
/// payload decodes draw their `ids`/`losses`/`rows` vectors from
/// recycled scratch and allocate *nothing*. The unpooled
/// `read_frame_into` fallback is pinned alongside at exactly one
/// allocation per owned vector, so hidden per-frame costs cannot creep
/// into either path.
#[test]
fn warm_pooled_decode_of_nonempty_payloads_allocates_nothing() {
    let enc = Frame::LossRecords {
        seq: 1,
        worker: 0,
        stamp: 2,
        ids: (0..32).collect(),
        losses: (0..32).map(|i| i as f32).collect(),
    }
    .encode();
    let lookup = Frame::CacheLookup { req: 3, now: 4, exact: false, ids: vec![NO_ID; 16] }
        .encode();
    let view = Frame::CacheView {
        req: 3,
        worker: 1,
        rows: (0..16).map(|i| ViewRow { pos: i, loss: 0.0, stamp: 0 }).collect(),
    }
    .encode();
    let mut body = Vec::with_capacity(enc.len().max(lookup.len()).max(view.len()) + 64);
    let mut pools = FramePools::new();
    let read_pooled = |bytes: &[u8], body: &mut Vec<u8>, pools: &mut FramePools| {
        let mut cur = Cursor::new(bytes);
        let (frame, _wire) =
            proto::read_frame_pooled(&mut cur, body, pools).unwrap().expect("one frame");
        pools.recycle(frame);
    };
    // warm pass: the pool learns one vector of each payload type
    read_pooled(&enc, &mut body, &mut pools);
    read_pooled(&lookup, &mut body, &mut pools);
    read_pooled(&view, &mut body, &mut pools);
    let n = allocs_during(|| {
        for _ in 0..3 {
            read_pooled(&enc, &mut body, &mut pools);
            read_pooled(&lookup, &mut body, &mut pools);
            read_pooled(&view, &mut body, &mut pools);
        }
    });
    assert_eq!(n, 0, "warm pooled decodes must not allocate ({n} allocations)");
    // the unpooled fallback still materializes owned vectors: pinned
    // exactly so the cost stays one allocation per vector, no more
    let read_owned = |bytes: &[u8], body: &mut Vec<u8>| {
        let mut cur = Cursor::new(bytes);
        let got = proto::read_frame_into(&mut cur, body).unwrap().expect("one frame");
        drop(got);
    };
    read_owned(&enc, &mut body); // warm the body buffer only
    let n = allocs_during(|| read_owned(&enc, &mut body));
    assert_eq!(n, 2, "unpooled LossRecords decode = ids + losses vectors, got {n}");
    let n = allocs_during(|| read_owned(&lookup, &mut body));
    assert_eq!(n, 1, "unpooled CacheLookup decode = ids vector, got {n}");
    let n = allocs_during(|| read_owned(&view, &mut body));
    assert_eq!(n, 1, "unpooled CacheView decode = rows vector, got {n}");
}

/// A coalesced envelope decodes for free too once the pool holds its
/// member list and member payload vectors; unpooled, the wrapper adds
/// exactly one allocation (the member list) over its members' payload
/// costs.
#[test]
fn warm_batch_envelope_decode_allocates_nothing() {
    let env = Frame::Batch(vec![
        Frame::LossRecords {
            seq: u64::MAX,
            worker: 0,
            stamp: 2,
            ids: (0..8).collect(),
            losses: (0..8).map(|i| i as f32).collect(),
        },
        Frame::CacheLookup { req: 3, now: 4, exact: true, ids: (0..8).collect() },
    ])
    .encode();
    let mut body = Vec::with_capacity(env.len() + 64);
    let mut pools = FramePools::new();
    let read_pooled = |body: &mut Vec<u8>, pools: &mut FramePools| {
        let mut cur = Cursor::new(env.as_slice());
        let (frame, _wire) =
            proto::read_frame_pooled(&mut cur, body, pools).unwrap().expect("one frame");
        pools.recycle(frame);
    };
    read_pooled(&mut body, &mut pools); // warm
    let n = allocs_during(|| {
        for _ in 0..3 {
            read_pooled(&mut body, &mut pools);
        }
    });
    assert_eq!(n, 0, "warm pooled envelope decodes must not allocate ({n})");
    // unpooled contrast: members vec + (ids + losses) + ids
    let mut cur = Cursor::new(env.as_slice());
    let n = allocs_during(|| {
        let got = proto::read_frame_into(&mut cur, &mut body).unwrap().expect("one frame");
        drop(got);
    });
    assert_eq!(n, 4, "unpooled envelope = member list + member payloads, got {n}");
}
