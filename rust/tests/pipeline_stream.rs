//! Streaming pipeline integration: continuous training with bounded
//! prefetch, drift, and the status service.

use obftf::config::TrainConfig;
use obftf::coordinator::service::{read_status, serve, StatusBoard};
use obftf::coordinator::StreamingTrainer;
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        model: "linreg".to_string(),
        method: Method::Obftf,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: steps,
        lr: 0.01,
        n_train: Some(512),
        n_test: Some(256),
        seed: 19,
        eval_every: 2,
        prefetch_depth: 3,
        ..Default::default()
    }
}

#[test]
fn streaming_runs_exact_step_count() {
    let m = manifest();
    let mut st = StreamingTrainer::with_manifest(&cfg(25), &m).unwrap();
    let report = st.run().unwrap();
    assert_eq!(report.steps, 25);
    assert!(report.final_eval.loss.is_finite());
    assert!(!report.evals.is_empty());
    // every stream batch is full-size
    assert_eq!(report.forward_examples, 25 * m.batch as u64);
}

#[test]
fn backpressure_engages_when_training_is_slow() {
    let m = manifest();
    let mut st = StreamingTrainer::with_manifest(&cfg(20), &m).unwrap();
    st.run().unwrap();
    // the linreg step is fast but still slower than synthetic generation;
    // with depth 3 the producer must have blocked at least once
    assert!(
        st.producer_blocked_ns() > 0,
        "expected nonzero producer stall (backpressure)"
    );
}

#[test]
fn drift_changes_the_loss_trajectory() {
    let m = manifest();
    let run = |drift: f32| {
        let mut c = cfg(30);
        c.drift = drift;
        let mut st = StreamingTrainer::with_manifest(&c, &m).unwrap();
        st.run().unwrap().final_eval.loss
    };
    let clean = run(0.0);
    let drifted = run(0.8);
    assert_ne!(clean, drifted, "drift should perturb training");
}

#[test]
fn status_service_reports_live_state() {
    let m = manifest();
    let board = StatusBoard::new();
    let server = serve(board.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    // drive a short streaming run, updating the board per step like the
    // launcher does
    let mut st = StreamingTrainer::with_manifest(&cfg(10), &m).unwrap();
    board.update(|s| {
        s.model = "linreg".into();
        s.method = "obftf".into();
    });
    let report = st.run().unwrap();
    board.update(|s| {
        s.step = report.steps;
        s.done = true;
    });

    let got = read_status(&addr).unwrap();
    assert_eq!(got.step, 10);
    assert!(got.done);
    assert_eq!(got.model, "linreg");
}

#[test]
fn streaming_requires_positive_steps() {
    let m = manifest();
    let mut c = cfg(0);
    c.epochs = 1; // valid config, but streaming ctor must refuse
    assert!(StreamingTrainer::with_manifest(&c, &m).is_err());
}
