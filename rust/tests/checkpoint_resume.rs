//! Checkpoint/resume durability: the continuous-training story.

use obftf::config::TrainConfig;
use obftf::coordinator::Trainer;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::testkit::TempDir;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn cfg() -> TrainConfig {
    TrainConfig {
        model: "linreg".to_string(),
        method: Method::ObftfProx,
        sampling_ratio: 0.25,
        epochs: 1,
        lr: 0.01,
        n_train: Some(384),
        n_test: Some(256),
        seed: 3,
        ..Default::default()
    }
}

#[test]
fn save_then_load_restores_exact_eval() {
    let m = manifest();
    let dir = TempDir::new("resume").unwrap();
    let ck = dir.file("model.ck");

    let mut a = Trainer::with_manifest(&cfg(), &m).unwrap();
    a.run_epoch().unwrap();
    let eval_a = a.evaluate().unwrap();
    a.save_checkpoint(&ck).unwrap();

    let mut b = Trainer::with_manifest(&cfg(), &m).unwrap();
    b.load_checkpoint(&ck).unwrap();
    let eval_b = b.evaluate().unwrap();

    assert_eq!(eval_a.loss, eval_b.loss, "restored eval must be bit-identical");
    assert_eq!(b.step_count(), a.step_count(), "step position restored");
}

#[test]
fn training_continues_after_resume() {
    let m = manifest();
    let dir = TempDir::new("resume2").unwrap();
    let ck = dir.file("model.ck");

    let mut a = Trainer::with_manifest(&cfg(), &m).unwrap();
    a.run_epoch().unwrap();
    a.save_checkpoint(&ck).unwrap();
    let loss_at_ck = a.evaluate().unwrap().loss;

    let mut b = Trainer::with_manifest(&cfg(), &m).unwrap();
    b.load_checkpoint(&ck).unwrap();
    b.run_epoch().unwrap();
    let after = b.evaluate().unwrap().loss;
    assert!(after <= loss_at_ck * 1.05, "resumed training regressed: {loss_at_ck} -> {after}");
    assert!(b.step_count() > a.step_count());
}

#[test]
fn wrong_model_checkpoint_rejected() {
    let m = manifest();
    let dir = TempDir::new("resume3").unwrap();
    let ck = dir.file("linreg.ck");
    let a = Trainer::with_manifest(&cfg(), &m).unwrap();
    a.save_checkpoint(&ck).unwrap();

    let mut mlp_cfg = cfg();
    mlp_cfg.model = "mlp".to_string();
    mlp_cfg.dataset = None;
    let mut b = Trainer::with_manifest(&mlp_cfg, &m).unwrap();
    let err = b.load_checkpoint(&ck).unwrap_err().to_string();
    assert!(err.contains("do not match"), "err: {err}");
}

#[test]
fn checkpoint_written_per_epoch_when_configured() {
    let m = manifest();
    let dir = TempDir::new("resume4").unwrap();
    let ck = dir.file("auto.ck");
    let mut c = cfg();
    c.checkpoint = Some(ck.to_string_lossy().to_string());
    c.epochs = 2;
    Trainer::with_manifest(&c, &m).unwrap().run().unwrap();
    let loaded = obftf::checkpoint::Checkpoint::load(&ck).unwrap();
    assert_eq!(loaded.epoch, 2);
    assert!(loaded.step > 0);
}
