//! Artifact-free Table 3 smoke: the paper's headline cnn comparison
//! runs end-to-end on the native conv backend — `experiments::sweep`
//! over cnn_lite × {uniform, obftf, selective_backprop} at tiny
//! budgets, the grid renders with no missing cells, and obftf's
//! selected-loss trajectory is finite and decreasing.

use obftf::config::TrainConfig;
use obftf::coordinator::Trainer;
use obftf::experiments::{dump_rows, render_table, sweep};
use obftf::runtime::{Flavour, Manifest};
use obftf::sampling::Method;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn base_cfg() -> TrainConfig {
    TrainConfig {
        model: "cnn_lite".into(),
        dataset: Some("imagenet_proxy".into()),
        epochs: 1,
        lr: 0.3,
        seed: 3,
        eval_every: 0,
        n_train: Some(256),
        n_test: Some(128),
        ..Default::default()
    }
}

/// The acceptance pin: the Table 3 grid over cnn_lite runs with no
/// artifacts present and renders a full (method × ratio) table.
#[test]
fn cnn_lite_table3_grid_runs_hermetically() {
    let m = manifest();
    // the native manifest always carries cnn_lite; a real artifact
    // manifest must too (it is the paper's Table 3 workload)
    let entry = m.model("cnn_lite").expect("cnn_lite in manifest");
    if !entry.has_flavour(Flavour::Native) && cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: artifact manifest without native cnn_lite executables");
        return;
    }
    let methods = [Method::Uniform, Method::Obftf, Method::SelectiveBackprop];
    let ratios = [0.1, 0.25];
    let cells = sweep(&base_cfg(), &methods, &ratios, &m, |_| {}).expect("sweep runs");
    assert_eq!(cells.len(), methods.len() * ratios.len(), "every cell must run");
    for c in &cells {
        assert!(
            c.report.final_eval.loss.is_finite() && c.report.final_eval.loss > 0.0,
            "{}/{}: loss {}",
            c.method.as_str(),
            c.ratio,
            c.report.final_eval.loss
        );
        assert!(
            (0.0..=1.0).contains(&c.report.final_eval.metric),
            "{}/{}: accuracy {}",
            c.method.as_str(),
            c.ratio,
            c.report.final_eval.metric
        );
        assert_eq!(c.report.model, "cnn_lite");
        assert!(c.report.forward_examples >= c.report.backward_examples);
    }
    // the rendered table has a row per method and no missing cells
    let table = render_table("Table 3 smoke", &cells, &ratios, |r| r.final_eval.metric);
    for m in &methods {
        assert!(table.contains(m.as_str()), "table missing row {}\n{table}", m.as_str());
    }
    assert!(!table.contains(" -"), "table has missing cells:\n{table}");
    // and the greppable dump carries one ROW per cell
    let rows = dump_rows("tab3smoke", &cells);
    assert_eq!(rows.lines().count(), cells.len());
    assert!(rows.lines().all(|l| l.starts_with("ROW tab3smoke method=")));
}

/// The budget accounting must reflect "ten forward, one backward" on
/// the conv workload: at ratio r the backward examples are ≈ r times
/// the forward examples.
#[test]
fn cnn_lite_budget_accounting_tracks_ratio() {
    let m = manifest();
    if !m.model("cnn_lite").map(|e| e.has_flavour(Flavour::Native)).unwrap_or(false) {
        eprintln!("skipping: no native cnn_lite");
        return;
    }
    let mut cfg = base_cfg();
    cfg.method = Method::Obftf;
    cfg.sampling_ratio = 0.25;
    let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps, 2, "256 examples / batch 128 = 2 steps");
    assert_eq!(report.forward_examples, 256);
    let realized = report.backward_examples as f64 / report.forward_examples as f64;
    assert!(
        (realized - 0.25).abs() < 0.05,
        "realized backward ratio {realized} far from 0.25"
    );
}

/// OBFTF's selected-loss trajectory on cnn_lite: every step's selected
/// mean loss is finite, and training drives it down (first-quarter
/// mean vs last-quarter mean over 24 steps).
#[test]
fn cnn_lite_obftf_selected_loss_decreases() {
    let m = manifest();
    if !m.model("cnn_lite").map(|e| e.has_flavour(Flavour::Native)).unwrap_or(false) {
        eprintln!("skipping: no native cnn_lite");
        return;
    }
    let mut cfg = base_cfg();
    cfg.method = Method::Obftf;
    cfg.sampling_ratio = 0.25;
    cfg.epochs = 12; // 2 steps/epoch → 24 steps
    let mut t = Trainer::with_manifest(&cfg, &m).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps, 24);
    let sel: Vec<f32> = t.recorder.steps.iter().map(|s| s.sel_loss).collect();
    assert!(sel.iter().all(|l| l.is_finite()), "selected losses must be finite: {sel:?}");
    let first: f32 = sel[..4].iter().sum::<f32>() / 4.0;
    let last: f32 = sel[sel.len() - 4..].iter().sum::<f32>() / 4.0;
    assert!(
        last < first,
        "selected-loss trajectory did not decrease: first4 {first} -> last4 {last}\n{sel:?}"
    );
    // the per-batch mean loss trains down too
    let batch: Vec<f32> = t.recorder.steps.iter().map(|s| s.batch_loss).collect();
    let bf: f32 = batch[..4].iter().sum::<f32>() / 4.0;
    let bl: f32 = batch[batch.len() - 4..].iter().sum::<f32>() / 4.0;
    assert!(bl < bf, "batch-loss trajectory did not decrease: {bf} -> {bl}");
}
