//! Elastic join/leave resharding pinned against the serial oracle.
//!
//! The fleet's worker *count* is dynamic: `pipeline_join` admits late
//! workers mid-run (Join handshake → quiesce → journal re-key →
//! epoch-tagged Reshard broadcast → ShardTransfer migration), and a
//! worker whose restart budget is spent is *retired* instead of
//! aborting while the fleet stays above `pipeline_min_workers`. Every
//! transition recomputes `id % n_workers` ownership, so the invariants
//! pinned here are the strongest the house style has:
//!
//! * sync mode stays **bit-identical** to the serial streaming trainer
//!   across a mid-run join AND a mid-run permanent leave — selection
//!   hashes, per-step losses, final weights, eval trajectory;
//! * the async staleness bound and requeue accounting survive a
//!   reshard;
//! * at the transport level, the journal re-key + shard migration
//!   preserve every routed row exactly (a propcheck property: after a
//!   join, the same lookup answers bit-identically with **zero**
//!   re-scoring), and the bounded journal evicts instead of growing.
//!
//! Env-coupled tests (worker-bin override, `--fail-after` injection,
//! restart-budget knobs travel by env into the production spawn path)
//! serialize on a file-local lock: env vars are process-global and the
//! harness runs tests on parallel threads.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use obftf::config::TrainConfig;
use obftf::coordinator::{
    FleetSpec, FleetTransport, LinkMode, PipelineTrainer, StreamingTrainer, Transport,
};
use obftf::data::dataset::{Batch, InMemoryDataset};
use obftf::data::{Rng, Targets, TensorData};
use obftf::runtime::{Flavour, Manifest, ScorePrecision, Session};
use obftf::sampling::Method;
use obftf::testkit::propcheck;

/// Serializes every test that reads or writes process-global env
/// (`OBFTF_PROC_FAIL_AFTER`, restart/floor knobs): the pipeline spawn
/// path consults them, so a concurrent test's injection must never
/// leak into another's fleet.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn use_cli_worker_bin() {
    std::env::set_var("OBFTF_WORKER_BIN", env!("CARGO_BIN_EXE_obftf"));
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".to_string(),
        method: Method::Obftf,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: steps,
        lr: 0.05,
        n_train: Some(512),
        n_test: Some(256),
        seed: 31,
        eval_every: 3,
        prefetch_depth: 3,
        ..Default::default()
    }
}

fn spec(workers: usize, capacity: usize, fail_after: Vec<Option<u64>>) -> FleetSpec {
    FleetSpec {
        model: "linreg".into(),
        flavour: Flavour::Native,
        workers,
        capacity,
        max_age: 0,
        sync: true,
        score_precision: ScorePrecision::F32,
        param_precision: ScorePrecision::F32,
        worker_bin: Some(env!("CARGO_BIN_EXE_obftf").into()),
        timeout: Duration::from_secs(60),
        fail_after,
        link: LinkMode::Pipes,
        affinity: true,
        restart_limit: 0,
        min_workers: 1,
        max_entries: 0,
        overlap: false,
    }
}

/// A linreg dataset over `capacity` synthetic rows plus a batch
/// gathering exactly `ids` (padded to the manifest batch size).
fn linreg_fixture(capacity: usize, ids: &[usize]) -> (Session, Batch) {
    let manifest = manifest();
    let mut rng = Rng::seed_from(61);
    let xs: Vec<f32> = (0..capacity).map(|_| rng.normal() as f32).collect();
    let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 0.5).collect();
    let ds = InMemoryDataset::new(vec![1], xs, Targets::F32(ys)).unwrap();
    let batch = ds.gather_batch(ids, manifest.batch).unwrap();
    let mut session = Session::new(&manifest, "linreg", Flavour::Native).unwrap();
    session.init(5).unwrap();
    (session, batch)
}

fn assert_params_bit_identical(a: &[obftf::data::HostTensor], b: &[obftf::data::HostTensor]) {
    assert_eq!(a.len(), b.len());
    for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
        match (&ta.data, &tb.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                for (j, (x, y)) in va.iter().zip(vb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {i}[{j}]: serial {x} vs pipeline {y}"
                    );
                }
            }
            _ => panic!("params must be f32"),
        }
    }
}

/// Run the serial oracle for `base`, then the sync Unix-socket
/// pipeline from `pc`, and assert the full bit-for-bit contract plus
/// the expected membership trajectory (`n_from` workers at the first
/// recorded step, `n_to` at the last, exactly `reshards` transitions).
fn assert_elastic_run_bit_identical(
    base: &TrainConfig,
    pc: &TrainConfig,
    n_from: u32,
    n_to: u32,
    reshards: u64,
) {
    let m = manifest();
    let mut serial = StreamingTrainer::with_manifest(base, &m).unwrap();
    let sreport = serial.run().unwrap();
    let sparams = serial.trainer().session().params_to_host().unwrap();

    let mut p = PipelineTrainer::with_manifest(pc, &m).unwrap();
    let preport = p.run().expect("elastic transition must heal, not fail the run");
    assert_eq!(preport.steps, sreport.steps);

    let srecs = &serial.trainer().recorder.steps;
    let precs = &p.recorder.steps;
    assert_eq!(srecs.len(), precs.len());
    for (a, b) in srecs.iter().zip(precs.iter()) {
        assert_eq!(a.sel_hash, b.sel_hash, "step {}: selected sets differ", a.step);
        assert_eq!(
            a.sel_loss.to_bits(),
            b.sel_loss.to_bits(),
            "step {} sel_loss diverged across the reshard",
            a.step
        );
        assert_eq!(a.batch_loss.to_bits(), b.batch_loss.to_bits(), "step {} batch_loss", a.step);
    }

    // membership telemetry: the trajectory moved n_from → n_to in
    // exactly the expected number of reshard transitions
    let first = precs.first().expect("steps recorded");
    let last = precs.last().expect("steps recorded");
    assert_eq!(first.n_workers, n_from, "fleet size at the first step");
    assert_eq!(last.n_workers, n_to, "fleet size at the last step");
    assert_eq!(last.reshards, reshards, "reshard transitions across the run");
    assert_eq!(p.reshards(), reshards);
    for w in precs.windows(2) {
        assert!(w[0].reshards <= w[1].reshards, "reshard counter is cumulative");
    }

    let pparams = p.session().params_to_host().unwrap();
    assert_params_bit_identical(&sparams, &pparams);

    assert_eq!(sreport.evals.len(), preport.evals.len());
    for (a, b) in sreport.evals.iter().zip(&preport.evals) {
        assert_eq!(a.step, b.step);
        assert!(
            (a.loss - b.loss).abs() <= 1e-12 * a.loss.abs().max(1.0),
            "eval at step {}: {} vs {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    assert_eq!(preport.forward_examples, sreport.forward_examples);
    assert_eq!(preport.backward_examples, sreport.backward_examples);
}

/// Tentpole pin #1: a worker **joins** a sync Unix-socket fleet
/// mid-run (`pipeline_join = "5"`) and the run stays bit-identical to
/// serial — the join only re-routes work, never changes results.
#[test]
fn sync_unix_pipeline_with_midrun_join_is_bit_identical_to_serial() {
    let _g = env_guard();
    use_cli_worker_bin();
    let base = cfg(12);
    let mut pc = base.clone();
    pc.pipeline = true;
    pc.pipeline_sync = true;
    pc.pipeline_proc = true;
    pc.pipeline_socket = "unix".to_string();
    pc.pipeline_workers = 2;
    pc.pipeline_join = "5".to_string();
    assert_elastic_run_bit_identical(&base, &pc, 2, 3, 1);
}

/// Tentpole pin #2: a worker **leaves permanently** mid-run (killed by
/// `--fail-after` injection with a spent restart budget, fleet above
/// the `pipeline_min_workers` floor) — the leader retires it, reshards
/// ownership onto the survivor, and the run is still bit-identical to
/// serial with zero restarts on the books.
#[test]
fn sync_unix_pipeline_with_permanent_leave_is_bit_identical_to_serial() {
    let _g = env_guard();
    use_cli_worker_bin();
    // worker 1 dies on its 7th frame, a few steps in; budget 0 + floor
    // 1 (the default) turns the death into retirement, not an abort
    std::env::set_var("OBFTF_PROC_FAIL_AFTER", "1:6");
    std::env::set_var("OBFTF_PIPELINE_RESTART_LIMIT", "0");
    let base = cfg(12);
    let mut pc = base.clone();
    pc.pipeline = true;
    pc.pipeline_sync = true;
    pc.pipeline_proc = true;
    pc.pipeline_socket = "unix".to_string();
    pc.pipeline_workers = 2;
    assert_elastic_run_bit_identical(&base, &pc, 2, 1, 1);
    std::env::remove_var("OBFTF_PROC_FAIL_AFTER");
    std::env::remove_var("OBFTF_PIPELINE_RESTART_LIMIT");
}

/// Async mode across a reshard: with a tight staleness bound
/// (`loss_max_age = 1`) and a lookahead deeper than the bound, the
/// requeue machinery must engage for the run to finish at all — and a
/// mid-run join must not break it. Accounting stays coherent: one
/// counting lookup per step, every issued batch scored, membership
/// telemetry reflecting the grown fleet.
#[test]
fn async_proc_pipeline_requeues_and_accounts_across_a_join() {
    let _g = env_guard();
    use_cli_worker_bin();
    let m = manifest();
    let mut pc = cfg(20);
    pc.model = "linreg".into();
    pc.method = Method::MaxProb;
    pc.lr = 0.01;
    pc.pipeline = true;
    pc.pipeline_proc = true;
    pc.pipeline_workers = 2;
    pc.pipeline_depth = 6;
    pc.loss_max_age = 1;
    pc.pipeline_join = "8".to_string();
    let mut p = PipelineTrainer::with_manifest(&pc, &m).unwrap();
    let report = p.run().expect("join must not break the staleness/requeue path");
    assert_eq!(report.steps, 20);
    assert!(report.final_eval.loss.is_finite());
    // one counting lookup per step, reshard-epoch retries excluded
    let stats = p.cache_stats();
    assert_eq!(stats.hits + stats.misses, 20);
    // every issued batch was scored; requeues only add to this
    assert!(p.budget.inference_forwards >= 20 * m.batch as u64);
    let scored: u64 = p.worker_stats().iter().map(|w| w.scored_batches).sum();
    assert!(scored >= 20, "at least one scoring per step, requeues on top");
    assert_eq!(p.reshards(), 1);
    let last = p.recorder.steps.last().expect("steps recorded");
    assert_eq!(last.n_workers, 3, "the joiner is in the ownership map");
    assert_eq!(last.workers_alive, 3);
}

/// The journal re-key property, end to end at the transport level:
/// score a batch, admit a worker (quiesce → re-key → Reshard →
/// ShardTransfer migration), then re-await the *same* batch without
/// resubmitting. Sync mode never re-scores on its own, so the second
/// answer can only come from migrated shard state — it must be
/// bit-identical, with zero additional scored batches and every real
/// row recorded exactly once.
#[test]
fn journal_rekey_preserves_every_routed_row_across_a_join() {
    let m = manifest();
    let batch_size = m.batch;
    let capacity = batch_size * 4;
    propcheck(
        "journal re-key across join",
        3,
        |rng| {
            let workers = 1 + rng.below(3);
            // a random nonempty set of distinct ids (partial shuffle)
            let mut pool: Vec<usize> = (0..capacity).collect();
            let k = 1 + rng.below(batch_size);
            for i in 0..k {
                let j = i + rng.below(capacity - i);
                pool.swap(i, j);
            }
            let mut ids = pool[..k].to_vec();
            ids.sort_unstable();
            (workers, ids)
        },
        |(workers, ids)| {
            let (mut session, batch) = linreg_fixture(capacity, ids);
            let expect =
                session.fwd_loss(&batch.x, &batch.y).map_err(|e| format!("oracle: {e:#}"))?;
            let mut t = FleetTransport::spawn(spec(*workers, capacity, Vec::new()))
                .map_err(|e| format!("spawn: {e:#}"))?;
            t.publish(0, &Arc::new(session.snapshot().unwrap()))
                .map_err(|e| format!("publish: {e:#}"))?;
            let batch = Arc::new(batch);
            t.submit(&batch).map_err(|e| format!("submit: {e:#}"))?;
            let l1 = t.await_losses(&batch, 0).map_err(|e| format!("first await: {e:#}"))?;
            for (row, (got, want)) in l1.iter().zip(&expect).enumerate() {
                if batch.valid_mask[row] > 0.0 && got.to_bits() != want.to_bits() {
                    return Err(format!("row {row}: fleet {got} vs oracle {want}"));
                }
            }
            let scored_before: u64 = t.worker_scored().iter().sum();
            t.admit_worker().map_err(|e| format!("admit: {e:#}"))?;
            if t.reshards() != 1 {
                return Err(format!("expected 1 reshard, got {}", t.reshards()));
            }
            if t.n_workers() != workers + 1 || t.workers_alive() != workers + 1 {
                return Err(format!(
                    "fleet must be {} after the join, got {}/{} alive",
                    workers + 1,
                    t.n_workers(),
                    t.workers_alive()
                ));
            }
            // no resubmit: this answer exists only if migration kept
            // every (id, loss, stamp) exactly
            let l2 = t.await_losses(&batch, 0).map_err(|e| format!("post-join await: {e:#}"))?;
            for (row, (a, b)) in l1.iter().zip(&l2).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("row {row}: {a} pre-join vs {b} post-join"));
                }
            }
            let scored_after: u64 = t.worker_scored().iter().sum();
            if scored_after != scored_before {
                return Err(format!(
                    "post-join lookup must not re-score ({scored_before} → {scored_after})"
                ));
            }
            let summary = t.shutdown().map_err(|e| format!("shutdown: {e:#}"))?;
            let recorded: u64 = summary.workers.iter().map(|w| w.recorded_rows).sum();
            if recorded != batch.real as u64 {
                return Err(format!(
                    "migration must not double-count rows: recorded {recorded}, real {}",
                    batch.real
                ));
            }
            if summary.reshards != 1 {
                return Err(format!("summary reshards {} != 1", summary.reshards));
            }
            Ok(())
        },
    );
}

/// Retirement at the transport level: worker 1 dies mid-handoff with a
/// spent budget and headroom above the floor. The leader retires it,
/// reshards onto the survivor, and the *same* `await_losses` call
/// returns bit-identical losses — zero restarts, one reshard, and the
/// shrunken fleet keeps serving further batches.
#[test]
fn transport_retires_a_budget_spent_worker_and_stays_bit_identical() {
    let ids: Vec<usize> = (0..manifest().batch).collect();
    let capacity = ids.len() * 2;
    let (mut session, batch) = linreg_fixture(capacity, &ids);
    let expect = session.fwd_loss(&batch.x, &batch.y).unwrap();
    // worker 1 survives exactly the ParamUpdate, then dies on whatever
    // arrives next; restart_limit 0 + min_workers 1 → retirement
    let mut t =
        FleetTransport::spawn(spec(2, capacity, vec![None, Some(1)])).expect("fleet spawns");
    t.publish(0, &Arc::new(session.snapshot().unwrap())).unwrap();
    let batch = Arc::new(batch);
    t.submit(&batch).unwrap();
    let losses = t.await_losses(&batch, 0).expect("retirement heals the handoff");
    for (row, (got, want)) in losses.iter().zip(&expect).enumerate() {
        if batch.valid_mask[row] > 0.0 {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "row {row}: retired fleet must stay bit-identical"
            );
        }
    }
    assert_eq!(t.restarts(), 0, "retirement is not a restart");
    assert_eq!(t.reshards(), 1, "exactly one shrink transition");
    assert_eq!(t.n_workers(), 1, "the survivor owns the whole map");
    assert_eq!(t.workers_alive(), 1);
    // the shrunken fleet still serves: re-scoring the same batch routes
    // everything to the survivor under the new map
    t.submit(&batch).unwrap();
    let again = t.await_losses(&batch, 0).expect("survivor serves the resubmit");
    for (a, b) in losses.iter().zip(&again) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let summary = t.shutdown().expect("clean shutdown");
    assert_eq!(summary.restarts, 0);
    assert_eq!(summary.reshards, 1);
    assert_eq!(summary.workers_alive, 1);
}

/// The memory-growth fix at the transport level: with
/// `cache_max_entries` bounding the leader's routed-row journal,
/// streaming far more distinct ids than the bound evicts
/// oldest-stamp-first instead of growing without limit — and the run
/// stays healthy (workers still answer every lookup bit-identically).
#[test]
fn bounded_journal_evicts_oldest_and_the_run_stays_healthy() {
    let m = manifest();
    let batch_size = m.batch;
    let capacity = batch_size * 64;
    let mut rng = Rng::seed_from(71);
    let xs: Vec<f32> = (0..capacity).map(|_| rng.normal() as f32).collect();
    let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x + 0.5).collect();
    let ds = InMemoryDataset::new(vec![1], xs, Targets::F32(ys)).unwrap();
    let mut session = Session::new(&m, "linreg", Flavour::Native).unwrap();
    session.init(5).unwrap();
    let mut s = spec(2, capacity, Vec::new());
    s.sync = false;
    s.max_age = 0; // async classification with no staleness bound
    s.max_entries = 4 * batch_size as u64;
    let mut t = FleetTransport::spawn(s).expect("fleet spawns");
    t.publish(0, &Arc::new(session.snapshot().unwrap())).unwrap();
    // stream every id once: 64 batches of distinct ids — 16× the bound
    for chunk in 0..(capacity / batch_size) {
        let ids: Vec<usize> = (chunk * batch_size..(chunk + 1) * batch_size).collect();
        let batch = Arc::new(ds.gather_batch(&ids, batch_size).unwrap());
        let expect = session.fwd_loss(&batch.x, &batch.y).unwrap();
        t.submit(&batch).unwrap();
        let losses = t.await_losses(&batch, 0).expect("bounded journal must not break scoring");
        for (row, (got, want)) in losses.iter().zip(&expect).enumerate() {
            if batch.valid_mask[row] > 0.0 {
                assert_eq!(got.to_bits(), want.to_bits(), "chunk {chunk} row {row}");
            }
        }
    }
    assert!(
        t.evictions() > 0,
        "16× the bound in distinct ids must have evicted journal entries"
    );
    t.shutdown().expect("clean shutdown");
}
