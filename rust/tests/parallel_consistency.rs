//! The leader/worker data-parallel trainer must be *numerically
//! identical* to the serial trainer: same shuffles, same selections,
//! same weighted-averaged gradients, bit-equal parameters (up to the
//! float-summation reorder of weighted grad averaging).
//!
//! Runs against the manifest's default flavour — the synthesized
//! native manifest on a fresh checkout, real artifacts when built.

use obftf::config::TrainConfig;
use obftf::coordinator::{ParallelTrainer, Trainer};
use obftf::data::TensorData;
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn cfg(model: &str, workers: usize) -> TrainConfig {
    TrainConfig {
        model: model.to_string(),
        method: Method::Obftf,
        sampling_ratio: 0.25,
        epochs: 1,
        lr: if model == "linreg" { 0.01 } else { 0.05 },
        n_train: Some(384),
        n_test: Some(256),
        seed: 11,
        workers,
        ..Default::default()
    }
}

fn assert_params_equal(a: &[obftf::data::HostTensor], b: &[obftf::data::HostTensor], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.shape, tb.shape, "param {i} shape");
        match (&ta.data, &tb.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                for (j, (x, y)) in va.iter().zip(vb).enumerate() {
                    assert!(
                        (x - y).abs() <= tol * x.abs().max(1.0),
                        "param {i}[{j}]: serial {x} vs parallel {y}"
                    );
                }
            }
            _ => panic!("params must be f32"),
        }
    }
}

#[test]
fn parallel_equals_serial_linreg() {
    let m = manifest();
    let serial_cfg = cfg("linreg", 1);
    let mut serial = Trainer::with_manifest(&serial_cfg, &m).unwrap();
    serial.run_epoch().unwrap();
    let sp = serial.session().params_to_host().unwrap();

    let par_cfg = cfg("linreg", 3);
    let mut par = ParallelTrainer::with_manifest(&par_cfg, &m).unwrap();
    assert_eq!(par.n_workers(), 3);
    par.run_epoch().unwrap();
    let pp = par.params_to_host().unwrap();

    // weighted grad averaging reorders float sums; allow tiny drift
    assert_params_equal(&sp, &pp, 1e-5);
}

#[test]
fn parallel_equals_serial_mlp_eval() {
    let m = manifest();
    let mut serial = Trainer::with_manifest(&cfg("mlp", 1), &m).unwrap();
    serial.run_epoch().unwrap();
    let se = serial.evaluate().unwrap();

    let mut par = ParallelTrainer::with_manifest(&cfg("mlp", 2), &m).unwrap();
    par.run_epoch().unwrap();
    let pe = par.evaluate().unwrap();

    assert!(
        (se.loss - pe.loss).abs() < 1e-3 * se.loss.abs().max(1.0),
        "serial loss {} vs parallel {}",
        se.loss,
        pe.loss
    );
    assert!(
        (se.metric - pe.metric).abs() < 0.02,
        "serial metric {} vs parallel {}",
        se.metric,
        pe.metric
    );
}

#[test]
fn parallel_equals_serial_mlp_params() {
    let m = manifest();
    let mut serial = Trainer::with_manifest(&cfg("mlp", 1), &m).unwrap();
    serial.run_epoch().unwrap();
    let sp = serial.session().params_to_host().unwrap();

    let mut par = ParallelTrainer::with_manifest(&cfg("mlp", 2), &m).unwrap();
    par.run_epoch().unwrap();
    let pp = par.params_to_host().unwrap();

    assert_params_equal(&sp, &pp, 1e-4);
}

#[test]
fn sharded_eval_counts_every_example_once() {
    let m = manifest();
    // test-set size NOT divisible by batch or workers: padding must be
    // masked out in every shard
    let mut c = cfg("linreg", 3);
    c.n_test = Some(300);
    let mut par = ParallelTrainer::with_manifest(&c, &m).unwrap();
    let e1 = par.evaluate().unwrap();
    let e2 = par.evaluate().unwrap();
    assert_eq!(e1.loss, e2.loss, "eval must be deterministic");
    assert!(e1.loss.is_finite());
}

#[test]
fn worker_count_exceeding_batch_still_works() {
    let m = manifest();
    // 128-row batches over 5 workers → uneven shards incl. padding-only
    let mut c = cfg("linreg", 5);
    c.n_train = Some(130); // second batch has only 2 real rows
    let mut par = ParallelTrainer::with_manifest(&c, &m).unwrap();
    par.run_epoch().unwrap();
    let e = par.evaluate().unwrap();
    assert!(e.loss.is_finite());
}
