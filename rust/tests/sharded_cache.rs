//! Sharded loss-cache correctness: N lock-striped shards written by
//! interleaved concurrent writers must hold exactly the contents the
//! single-owner serial cache holds under any per-writer-order-preserving
//! schedule, and must make identical freshness decisions.

use obftf::coordinator::{LossCache, ShardedLossCache};
use obftf::data::rng::Rng;
use obftf::testkit::cases::writer_plans;

/// Property: partition writes among W writers (writer w owns ids ≡ w
/// mod W, so per-id write order is each writer's program order — the
/// shared [`obftf::testkit::cases::writer_plans`] contract), run the
/// writers concurrently against an N-shard cache, and the final
/// contents equal the serial cache applying the same per-writer
/// sequences in any interleaving — here round-robin.
#[test]
fn interleaved_writers_match_serial_for_any_schedule() {
    let mut rng = Rng::seed_from(0xcafe);
    for trial in 0..20 {
        let capacity = 16 + rng.below(200);
        let n_shards = 1 + rng.below(7);
        let writers = 1 + rng.below(4);
        let max_age = rng.below(4) as u64 * 3; // 0 (∞), 3, 6, 9
        let ops_per_writer = 20 + rng.below(60);

        let plans = writer_plans(&mut rng, capacity, writers, ops_per_writer);

        // serial reference: round-robin interleave (any schedule that
        // preserves each writer's order yields the same contents,
        // because each id has exactly one writer)
        let mut serial = LossCache::new(capacity, max_age);
        let mut idx = vec![0usize; writers];
        loop {
            let mut progressed = false;
            for w in 0..writers {
                if idx[w] < plans[w].len() {
                    let (id, loss, stamp) = plans[w][idx[w]];
                    serial.record_batch(&[id], &[1.0], &[loss], stamp);
                    idx[w] += 1;
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }

        // sharded: the same per-writer sequences, concurrently
        let sharded = ShardedLossCache::new(capacity, max_age, n_shards);
        std::thread::scope(|scope| {
            for plan in &plans {
                let cache = &sharded;
                scope.spawn(move || {
                    for &(id, loss, stamp) in plan {
                        cache.record_batch(&[id], &[1.0], &[loss], stamp);
                    }
                });
            }
        });

        for id in 0..capacity {
            assert_eq!(
                serial.entry(id),
                sharded.entry(id),
                "trial {trial}: id {id} (shards {n_shards}, writers {writers})"
            );
        }

        // identical freshness decisions on random batch lookups
        // (including out-of-range ids and padding rows)
        for _ in 0..10 {
            let bsz = 1 + rng.below(8);
            let ids: Vec<usize> = (0..bsz).map(|_| rng.below(capacity + 2)).collect();
            let mut valid = vec![1.0f32; bsz];
            if rng.below(3) == 0 {
                valid[bsz - 1] = 0.0;
            }
            let now = rng.below(60) as u64;
            assert_eq!(
                serial.lookup_batch(&ids, &valid, now),
                sharded.lookup_batch(&ids, &valid, now),
                "trial {trial}: lookup ids {ids:?} now {now}"
            );
        }
    }
}

#[test]
fn concurrent_batch_writers_land_every_row() {
    let capacity = 256;
    let sharded = ShardedLossCache::new(capacity, 0, 5);
    std::thread::scope(|scope| {
        for w in 0..4usize {
            let cache = &sharded;
            scope.spawn(move || {
                // writer w records rows w*64..(w+1)*64 in batches of 16
                for chunk in 0..4 {
                    let base = w * 64 + chunk * 16;
                    let ids: Vec<usize> = (base..base + 16).collect();
                    let valid = vec![1.0f32; 16];
                    let losses: Vec<f32> = ids.iter().map(|&i| i as f32).collect();
                    cache.record_batch(&ids, &valid, &losses, w as u64);
                }
            });
        }
    });
    let ids: Vec<usize> = (0..capacity).collect();
    let valid = vec![1.0f32; capacity];
    let got = sharded.lookup_batch(&ids, &valid, 10).expect("fully covered");
    for (i, l) in got.iter().enumerate() {
        assert_eq!(*l, i as f32, "row {i}");
    }
    assert_eq!(sharded.stats().hits, 1);
    // every shard saw its share of the covering lookup
    for k in 0..sharded.n_shards() {
        assert!(sharded.shard_stats(k).hits > 0, "shard {k} never hit");
    }
}
