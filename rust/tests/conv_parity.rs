//! Property tests for the conv kernel family (`runtime/kernels/conv`):
//! the blocked im2col/GEMM lowering must match the direct-loop
//! `kernels/reference.rs` conv oracle across awkward geometries, be
//! bit-identical across thread counts, and preserve the
//! gathered-vs-masked bit-equality invariant at the backend level on
//! the cnn_lite chain — the conv mirror of `tests/kernel_parity.rs`.

use obftf::data::rng::Rng;
use obftf::data::{HostTensor, TensorData};
use obftf::runtime::kernels::{self, reference, Arena, ConvShape};
use obftf::runtime::{Backend, KernelConfig, Manifest, NativeBackend};
use obftf::testkit::cases::{
    check_close, conv_geometry, normal_vec, relu_vec, zero_rows_except_period,
};
use obftf::testkit::{propcheck, TempDir};

const REL_TOL: f32 = 1e-4;

/// One randomized conv-parity case; data regenerates from `data_seed`
/// so failures print a compact, replayable description.
#[derive(Debug)]
struct Case {
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    n: usize,
    threads: usize,
    relu: bool,
    mask_period: usize,
    data_seed: u64,
}

fn gen_case(rng: &mut Rng) -> Case {
    let (h, w, cin, cout, k, stride) = conv_geometry(rng);
    Case {
        h,
        w,
        cin,
        cout,
        k,
        stride,
        n: 1 + rng.below(5),
        threads: 1 + rng.below(5),
        relu: rng.below(2) == 1,
        // every `mask_period`-th image's dz rows survive, the rest are
        // zeroed (0 ⇒ the all-masked-out batch)
        mask_period: rng.below(4),
        data_seed: rng.next_u64(),
    }
}

fn shape_of(c: &Case) -> ConvShape {
    ConvShape::same(c.h, c.w, c.cin, c.cout, c.k, c.k, c.stride)
}

#[test]
fn blocked_conv_matches_reference_on_random_geometries() {
    propcheck("conv-blocked-vs-reference", 60, gen_case, |c| {
        let s = shape_of(c);
        let (n, threads) = (c.n, c.threads);
        let mut rng = Rng::seed_from(c.data_seed);
        let x = normal_vec(&mut rng, n * s.in_elems());
        let k = normal_vec(&mut rng, s.patch_len() * s.cout);
        let b = normal_vec(&mut rng, s.cout);
        // ReLU-like input activation (exact zeros) for the gated paths
        let h_in = relu_vec(&mut rng, n * s.in_elems());
        let mut dz = normal_vec(&mut rng, n * s.out_elems());
        // masked-out images carry exact-zero output gradients
        zero_rows_except_period(&mut dz, s.out_elems(), c.mask_period);

        let cfg = KernelConfig::blocked(threads);
        let mut arena = Arena::new();

        let mut got = vec![0.0f32; n * s.out_elems()];
        let mut want = vec![0.0f32; n * s.out_elems()];
        kernels::conv2d_bias_act(&cfg, &mut arena, &x, &k, &b, &mut got, n, &s, c.relu);
        reference::conv2d_bias_act(&x, &k, &b, &mut want, n, &s, c.relu);
        check_close(&got, &want, REL_TOL, "conv forward")?;

        let (mut gk, mut gb) = (vec![0.0f32; s.patch_len() * s.cout], vec![0.0f32; s.cout]);
        let (mut wk, mut wb) = (vec![0.0f32; s.patch_len() * s.cout], vec![0.0f32; s.cout]);
        kernels::conv2d_grad_w(&cfg, &mut arena, &x, &dz, &mut gk, &mut gb, n, &s);
        reference::conv2d_grad_w(&x, &dz, &mut wk, &mut wb, n, &s);
        check_close(&gk, &wk, REL_TOL, "conv grad_w")?;
        check_close(&gb, &wb, REL_TOL, "conv grad_b")?;

        let mut gx = vec![1.0f32; n * s.in_elems()]; // dirty: kernel must overwrite
        let mut wx = vec![0.0f32; n * s.in_elems()];
        kernels::conv2d_grad_x(&cfg, &mut arena, &dz, &k, &h_in, &mut gx, n, &s);
        reference::conv2d_grad_x(&dz, &k, &h_in, &mut wx, n, &s);
        check_close(&gx, &wx, REL_TOL, "conv grad_x")?;
        Ok(())
    });
}

#[test]
fn blocked_conv_is_thread_count_invariant_bitwise() {
    propcheck("conv-threaded-vs-serial", 40, gen_case, |c| {
        let s = shape_of(c);
        let n = c.n;
        let mut rng = Rng::seed_from(c.data_seed);
        let x = normal_vec(&mut rng, n * s.in_elems());
        let k = normal_vec(&mut rng, s.patch_len() * s.cout);
        let b = normal_vec(&mut rng, s.cout);
        let h_in = relu_vec(&mut rng, n * s.in_elems());
        let dz = normal_vec(&mut rng, n * s.out_elems());
        let mut arena = Arena::new();
        let serial = KernelConfig::blocked(1);
        let threaded = KernelConfig::blocked(4);

        let (mut o1, mut o4) =
            (vec![0.0f32; n * s.out_elems()], vec![0.0f32; n * s.out_elems()]);
        kernels::conv2d_bias_act(&serial, &mut arena, &x, &k, &b, &mut o1, n, &s, c.relu);
        kernels::conv2d_bias_act(&threaded, &mut arena, &x, &k, &b, &mut o4, n, &s, c.relu);
        if o1 != o4 {
            return Err("conv forward differs across thread counts".into());
        }
        let (mut k1, mut b1) = (vec![0.0f32; s.patch_len() * s.cout], vec![0.0f32; s.cout]);
        let (mut k4, mut b4) = (vec![0.0f32; s.patch_len() * s.cout], vec![0.0f32; s.cout]);
        kernels::conv2d_grad_w(&serial, &mut arena, &x, &dz, &mut k1, &mut b1, n, &s);
        kernels::conv2d_grad_w(&threaded, &mut arena, &x, &dz, &mut k4, &mut b4, n, &s);
        if k1 != k4 || b1 != b4 {
            return Err("conv grad_w differs across thread counts".into());
        }
        let (mut x1, mut x4) =
            (vec![0.0f32; n * s.in_elems()], vec![0.0f32; n * s.in_elems()]);
        kernels::conv2d_grad_x(&serial, &mut arena, &dz, &k, &h_in, &mut x1, n, &s);
        kernels::conv2d_grad_x(&threaded, &mut arena, &dz, &k, &h_in, &mut x4, n, &s);
        if x1 != x4 {
            return Err("conv grad_x differs across thread counts".into());
        }
        Ok(())
    });
}

/// The geometries the lowering logic must not mishandle, pinned
/// explicitly: a 1×1 image under a 3×3 kernel (all padding but the
/// center), kernel == image, stride past the image, channels around
/// the `NR` panel width, and the real cnn_lite layer shapes.
#[test]
fn pinned_awkward_geometries_match_reference() {
    use obftf::runtime::kernels::NR;
    let geoms = [
        (1, 1, 1, 1, 3, 1),
        (1, 1, 3, NR, 3, 2),
        (3, 3, 2, 5, 3, 3),
        (3, 3, 1, 1, 3, 1),
        (2, 5, 3, NR + 1, 3, 2),
        (4, 4, NR, NR, 1, 1),
        (16, 16, 3, 16, 3, 2),  // cnn_lite layer 1
        (8, 8, 16, NR - 1, 3, 2), // non-tile cout at the layer-2 shape
    ];
    for (h, w, cin, cout, k, stride) in geoms {
        let s = ConvShape::same(h, w, cin, cout, k, k, stride);
        let n = 2;
        for threads in [1, 3] {
            let mut rng = Rng::seed_from((h * 100 + w * 10 + cout + stride) as u64);
            let x = normal_vec(&mut rng, n * s.in_elems());
            let kv = normal_vec(&mut rng, s.patch_len() * s.cout);
            let b = normal_vec(&mut rng, s.cout);
            let cfg = KernelConfig::blocked(threads);
            let mut arena = Arena::new();
            let mut got = vec![0.0f32; n * s.out_elems()];
            let mut want = vec![0.0f32; n * s.out_elems()];
            kernels::conv2d_bias_act(&cfg, &mut arena, &x, &kv, &b, &mut got, n, &s, true);
            reference::conv2d_bias_act(&x, &kv, &b, &mut want, n, &s, true);
            check_close(
                &got,
                &want,
                REL_TOL,
                &format!("conv {h}x{w}x{cin}->{cout} k{k} s{stride} t{threads}"),
            )
            .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

/// An all-masked-out batch (every dz element exactly zero) must
/// produce exactly-zero kernel, bias and input gradients on both
/// paths, at several thread counts.
#[test]
fn all_masked_out_batch_yields_zero_conv_grads() {
    let s = ConvShape::same(5, 4, 3, 7, 3, 3, 2);
    let n = 4;
    let mut rng = Rng::seed_from(5);
    let x = normal_vec(&mut rng, n * s.in_elems());
    let k = normal_vec(&mut rng, s.patch_len() * s.cout);
    let h_in = relu_vec(&mut rng, n * s.in_elems());
    let dz = vec![0.0f32; n * s.out_elems()];
    for cfg in [KernelConfig::blocked(1), KernelConfig::blocked(4), KernelConfig::reference()] {
        let mut arena = Arena::new();
        let (mut dk, mut db) = (vec![1.0f32; s.patch_len() * s.cout], vec![1.0f32; s.cout]);
        kernels::conv2d_grad_w(&cfg, &mut arena, &x, &dz, &mut dk, &mut db, n, &s);
        assert!(dk.iter().all(|&v| v == 0.0), "dK must be exactly zero");
        assert!(db.iter().all(|&v| v == 0.0), "db must be exactly zero");
        let mut dx = vec![1.0f32; n * s.in_elems()];
        kernels::conv2d_grad_x(&cfg, &mut arena, &dz, &k, &h_in, &mut dx, n, &s);
        assert!(dx.iter().all(|&v| v == 0.0), "dx must be exactly zero");
    }
}

/// The backend-level invariant on the real Table 3 workload: on the
/// cnn_lite chain (16×16×3 → conv16/s2 → conv32/s2 → GAP → 100-way
/// head, batch 128), the gathered sub-batch step stays bit-identical
/// to the masked full-batch step — with threading disabled *and*
/// enabled — and the parameters are bit-identical across thread
/// counts. Mirror of kernel_parity's mlp pin.
#[test]
fn cnn_lite_gathered_step_bit_identical_to_masked_step() {
    let dir = TempDir::new("cparity").unwrap();
    let manifest = Manifest::native(dir.path());
    let entry = manifest.model("cnn_lite").unwrap();
    let n = manifest.batch;
    let stride: usize = entry.x_shape.iter().product();
    let mut rng = Rng::seed_from(71);
    let x = HostTensor::f32(
        vec![n, entry.x_shape[0], entry.x_shape[1], entry.x_shape[2]],
        (0..n * stride).map(|_| rng.normal() as f32 * 0.5).collect(),
    )
    .unwrap();
    let y = HostTensor::i32(
        vec![n],
        (0..n).map(|_| rng.below(entry.num_classes) as i32).collect(),
    )
    .unwrap();
    // scattered, unsorted selection across the batch
    let selected: Vec<usize> = vec![97, 3, 40, 41, 42, 11, 127, 64, 5, 80];
    let mut mask = vec![0.0f32; n];
    for &i in &selected {
        mask[i] = 1.0;
    }

    let mut end_params: Vec<Vec<HostTensor>> = vec![];
    for threads in [1usize, 4] {
        let cfg = KernelConfig::blocked(threads);
        let mut masked = NativeBackend::with_kernel_config("cnn_lite", entry, n, cfg).unwrap();
        let mut gathered = NativeBackend::with_kernel_config("cnn_lite", entry, n, cfg).unwrap();
        masked.init(9).unwrap();
        gathered.init(9).unwrap();
        for step in 0..2 {
            let lm = masked.train_step(&x, &y, &mask, 0.05).unwrap();
            let lg = gathered.train_step_selected(&x, &y, &selected, 0.05).unwrap();
            assert_eq!(lm, lg, "t{threads} step {step}: masked {lm} vs gathered {lg}");
        }
        let pm = masked.params_to_host().unwrap();
        let pg = gathered.params_to_host().unwrap();
        for (a, b) in pm.iter().zip(&pg) {
            match (&a.data, &b.data) {
                (TensorData::F32(va), TensorData::F32(vb)) => {
                    assert_eq!(va, vb, "t{threads}: masked vs gathered params")
                }
                _ => panic!("params must be f32"),
            }
        }
        end_params.push(pm);
    }
    for (a, b) in end_params[0].iter().zip(&end_params[1]) {
        match (&a.data, &b.data) {
            (TensorData::F32(va), TensorData::F32(vb)) => {
                assert_eq!(va, vb, "cnn_lite params must be thread-count invariant")
            }
            _ => panic!("params must be f32"),
        }
    }
}

/// fwd_loss on the cnn_lite chain is bitwise thread-count invariant
/// too (the property the sharded-cache inference fleet relies on when
/// scoring conv batches).
#[test]
fn cnn_lite_forward_losses_thread_invariant() {
    let dir = TempDir::new("cfwd").unwrap();
    let manifest = Manifest::native(dir.path());
    let entry = manifest.model("cnn_lite").unwrap();
    let n = manifest.batch;
    let stride: usize = entry.x_shape.iter().product();
    let mut rng = Rng::seed_from(13);
    let x = HostTensor::f32(
        vec![n, 16, 16, 3],
        (0..n * stride).map(|_| rng.normal() as f32 * 0.5).collect(),
    )
    .unwrap();
    let y = HostTensor::i32(
        vec![n],
        (0..n).map(|_| rng.below(entry.num_classes) as i32).collect(),
    )
    .unwrap();
    let mut all: Vec<Vec<f32>> = vec![];
    for threads in [1usize, 4] {
        let cfg = KernelConfig::blocked(threads);
        let mut b = NativeBackend::with_kernel_config("cnn_lite", entry, n, cfg).unwrap();
        b.init(3).unwrap();
        let losses = b.fwd_loss(&x, &y).unwrap();
        assert!(losses.iter().all(|l| l.is_finite()));
        all.push(losses);
    }
    assert_eq!(all[0], all[1], "losses must be thread-count invariant");
}
