//! Runtime contract tests: every model in the manifest builds a
//! session, its executables honour the declared shapes, and shape
//! violations are rejected before reaching the backend.
//!
//! Runs on the manifest's default flavour — native on a fresh
//! checkout, jnp when real artifacts are built.

use obftf::data::{HostTensor, Rng};
use obftf::runtime::{Flavour, Manifest, Session};

fn manifest() -> Manifest {
    Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads")
}

fn batch_for(m: &Manifest, model: &str, seed: u64) -> (HostTensor, HostTensor, Vec<f32>) {
    let entry = m.model(model).unwrap();
    let n = m.batch;
    let stride: usize = entry.x_shape.iter().product();
    let mut rng = Rng::seed_from(seed);
    let xs: Vec<f32> = (0..n * stride).map(|_| rng.normal() as f32 * 0.5).collect();
    let mut shape = vec![n];
    shape.extend_from_slice(&entry.x_shape);
    let x = HostTensor::f32(shape, xs).unwrap();
    let y = if entry.is_classification() {
        HostTensor::i32(
            vec![n],
            (0..n).map(|_| rng.below(entry.num_classes) as i32).collect(),
        )
        .unwrap()
    } else {
        HostTensor::f32(vec![n], (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
    };
    (x, y, vec![1.0; n])
}

#[test]
fn every_model_builds_inits_and_forwards() {
    let m = manifest();
    let flavour = m.default_flavour();
    for (name, entry) in &m.models {
        if flavour == Flavour::Native && entry.x_shape.len() == 3 && entry.conv_strides.is_empty()
        {
            // conv entries from an artifact manifest carry no stride
            // schedule; they run via the pjrt feature only. (The
            // synthesized native manifest's cnn / cnn_lite do carry
            // conv_strides and are exercised like every other model.)
            eprintln!("skipping {name}: artifact conv entry without conv_strides");
            continue;
        }
        let mut s = Session::new(&m, name, flavour)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        s.init(42).unwrap();
        let params = s.params_to_host().unwrap();
        assert_eq!(params.len(), entry.n_params(), "{name}");
        for (p, spec) in params.iter().zip(&entry.params) {
            assert_eq!(p.shape, spec.shape, "{name}/{}", spec.name);
        }
        let (x, y, mask) = batch_for(&m, name, 5);
        let losses = s.fwd_loss(&x, &y).unwrap();
        assert_eq!(losses.len(), m.batch, "{name}");
        assert!(losses.iter().all(|l| l.is_finite()), "{name}");
        if entry.is_classification() {
            assert!(losses.iter().all(|&l| l >= 0.0), "{name}: xent must be ≥ 0");
        }
        // one train step moves parameters
        let before = s.params_to_host().unwrap();
        let sel_loss = s.train_step(&x, &y, &mask, 0.01).unwrap();
        assert!(sel_loss.is_finite(), "{name}");
        let after = s.params_to_host().unwrap();
        let moved = before
            .iter()
            .zip(&after)
            .any(|(a, b)| a.as_f32().unwrap() != b.as_f32().unwrap());
        assert!(moved, "{name}: train_step did not update params");
    }
}

#[test]
fn grads_plus_apply_equals_train_step() {
    let m = manifest();
    let flavour = m.default_flavour();
    let (x, y, mask) = batch_for(&m, "mlp", 9);

    let mut fused = Session::new(&m, "mlp", flavour).unwrap();
    fused.init(1).unwrap();
    let fused_loss = fused.train_step(&x, &y, &mask, 0.1).unwrap();
    let fused_params = fused.params_to_host().unwrap();

    let mut split = Session::new(&m, "mlp", flavour).unwrap();
    split.init(1).unwrap();
    let (grads, split_loss) = split.grads(&x, &y, &mask).unwrap();
    split.apply(&grads, 0.1).unwrap();
    let split_params = split.params_to_host().unwrap();

    assert!((fused_loss - split_loss).abs() < 1e-6);
    for (a, b) in fused_params.iter().zip(&split_params) {
        let (va, vb) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for (p, q) in va.iter().zip(vb) {
            assert!((p - q).abs() < 1e-6, "fused {p} vs split {q}");
        }
    }
}

#[test]
fn shape_violations_rejected_before_backend() {
    let m = manifest();
    let mut s = Session::new(&m, "linreg", m.default_flavour()).unwrap();
    s.init(0).unwrap();
    let n = m.batch;
    let good_x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
    let good_y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();

    // wrong batch dim
    let bad_x = HostTensor::f32(vec![n + 1, 1], vec![0.0; n + 1]).unwrap();
    assert!(s.fwd_loss(&bad_x, &good_y).is_err());
    // wrong y dtype
    let bad_y = HostTensor::i32(vec![n], vec![0; n]).unwrap();
    assert!(s.fwd_loss(&good_x, &bad_y).is_err());
    // wrong mask length
    let short_mask = vec![1.0f32; n - 1];
    assert!(s.train_step(&good_x, &good_y, &short_mask, 0.1).is_err());
    // wrong grads arity for apply
    assert!(s.apply(&[], 0.1).is_err());
    // still usable after rejected calls
    assert!(s.fwd_loss(&good_x, &good_y).is_ok());
}

#[test]
fn uninitialized_session_refuses_to_run() {
    let m = manifest();
    let mut s = Session::new(&m, "linreg", m.default_flavour()).unwrap();
    let n = m.batch;
    let x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
    let y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();
    let err = s.fwd_loss(&x, &y).unwrap_err().to_string();
    assert!(err.contains("init"), "err: {err}");
}

#[test]
fn init_is_deterministic_per_seed_across_sessions() {
    let m = manifest();
    let flavour = m.default_flavour();
    let mut a = Session::new(&m, "mlp", flavour).unwrap();
    let mut b = Session::new(&m, "mlp", flavour).unwrap();
    a.init(123).unwrap();
    b.init(123).unwrap();
    let pa = a.params_to_host().unwrap();
    let pb = b.params_to_host().unwrap();
    for (x, y) in pa.iter().zip(&pb) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
    let mut c = Session::new(&m, "mlp", flavour).unwrap();
    c.init(124).unwrap();
    let pc = c.params_to_host().unwrap();
    assert!(pa
        .iter()
        .zip(&pc)
        .any(|(x, y)| x.as_f32().unwrap() != y.as_f32().unwrap()));
}

#[test]
fn eval_zero_mask_returns_zero_sums() {
    let m = manifest();
    let mut s = Session::new(&m, "mlp", m.default_flavour()).unwrap();
    s.init(0).unwrap();
    let (x, y, _) = batch_for(&m, "mlp", 2);
    let zeros = vec![0.0f32; m.batch];
    let (l, mt, c) = s.eval_batch(&x, &y, &zeros).unwrap();
    assert_eq!((l, mt, c), (0.0, 0.0, 0.0));
}

#[test]
fn session_stats_count_executions() {
    let m = manifest();
    let mut s = Session::new(&m, "linreg", m.default_flavour()).unwrap();
    s.init(0).unwrap();
    let (x, y, _) = batch_for(&m, "linreg", 3);
    let n0 = s.stats().executions;
    s.fwd_loss(&x, &y).unwrap();
    s.fwd_loss(&x, &y).unwrap();
    assert_eq!(s.stats().executions, n0 + 2);
    assert!(s.stats().compile_ns > 0);
}

#[test]
fn native_flavour_runs_even_with_artifact_manifests() {
    // the native backend needs only the parameter specs, so it can run
    // dense-chain models from any manifest
    let m = manifest();
    if m.model("linreg").is_err() {
        return;
    }
    let mut s = Session::new(&m, "linreg", Flavour::Native).unwrap();
    s.init(5).unwrap();
    let (x, y, mask) = batch_for(&m, "linreg", 8);
    let losses = s.fwd_loss(&x, &y).unwrap();
    assert_eq!(losses.len(), m.batch);
    assert!(s.train_step(&x, &y, &mask, 0.01).unwrap().is_finite());
}
