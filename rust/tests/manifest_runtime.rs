//! Runtime contract tests: every model in the manifest compiles, its
//! executables honour the declared shapes, and shape violations are
//! rejected before reaching XLA.

use obftf::data::{HostTensor, Rng};
use obftf::runtime::{Flavour, Manifest, Session};

fn manifest() -> Option<Manifest> {
    let dir = obftf::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).expect("manifest loads"))
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn batch_for(m: &Manifest, model: &str, seed: u64) -> (HostTensor, HostTensor, Vec<f32>) {
    let entry = m.model(model).unwrap();
    let n = m.batch;
    let stride: usize = entry.x_shape.iter().product();
    let mut rng = Rng::seed_from(seed);
    let xs: Vec<f32> = (0..n * stride).map(|_| rng.normal() as f32 * 0.5).collect();
    let mut shape = vec![n];
    shape.extend_from_slice(&entry.x_shape);
    let x = HostTensor::f32(shape, xs).unwrap();
    let y = if entry.is_classification() {
        HostTensor::i32(
            vec![n],
            (0..n).map(|_| rng.below(entry.num_classes) as i32).collect(),
        )
        .unwrap()
    } else {
        HostTensor::f32(vec![n], (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
    };
    (x, y, vec![1.0; n])
}

#[test]
fn every_model_compiles_inits_and_forwards() {
    let Some(m) = manifest() else { return };
    for (name, entry) in &m.models {
        let mut s = Session::new(&m, name, Flavour::Jnp)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        s.init(42).unwrap();
        let params = s.params_to_host().unwrap();
        assert_eq!(params.len(), entry.n_params(), "{name}");
        for (p, spec) in params.iter().zip(&entry.params) {
            assert_eq!(p.shape, spec.shape, "{name}/{}", spec.name);
        }
        let (x, y, mask) = batch_for(&m, name, 5);
        let losses = s.fwd_loss(&x, &y).unwrap();
        assert_eq!(losses.len(), m.batch, "{name}");
        assert!(losses.iter().all(|l| l.is_finite()), "{name}");
        if entry.is_classification() {
            assert!(losses.iter().all(|&l| l >= 0.0), "{name}: xent must be ≥ 0");
        }
        // one train step moves parameters
        let before = s.params_to_host().unwrap();
        let sel_loss = s.train_step(&x, &y, &mask, 0.01).unwrap();
        assert!(sel_loss.is_finite(), "{name}");
        let after = s.params_to_host().unwrap();
        let moved = before
            .iter()
            .zip(&after)
            .any(|(a, b)| a.as_f32().unwrap() != b.as_f32().unwrap());
        assert!(moved, "{name}: train_step did not update params");
    }
}

#[test]
fn grads_plus_apply_equals_train_step() {
    let Some(m) = manifest() else { return };
    let (x, y, mask) = batch_for(&m, "mlp", 9);

    let mut fused = Session::new(&m, "mlp", Flavour::Jnp).unwrap();
    fused.init(1).unwrap();
    let fused_loss = fused.train_step(&x, &y, &mask, 0.1).unwrap();
    let fused_params = fused.params_to_host().unwrap();

    let mut split = Session::new(&m, "mlp", Flavour::Jnp).unwrap();
    split.init(1).unwrap();
    let (grads, split_loss) = split.grads(&x, &y, &mask).unwrap();
    split.apply(&grads, 0.1).unwrap();
    let split_params = split.params_to_host().unwrap();

    assert!((fused_loss - split_loss).abs() < 1e-6);
    for (a, b) in fused_params.iter().zip(&split_params) {
        let (va, vb) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for (p, q) in va.iter().zip(vb) {
            assert!((p - q).abs() < 1e-6, "fused {p} vs split {q}");
        }
    }
}

#[test]
fn shape_violations_rejected_before_xla() {
    let Some(m) = manifest() else { return };
    let mut s = Session::new(&m, "linreg", Flavour::Jnp).unwrap();
    s.init(0).unwrap();
    let n = m.batch;
    let good_x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
    let good_y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();

    // wrong batch dim
    let bad_x = HostTensor::f32(vec![n + 1, 1], vec![0.0; n + 1]).unwrap();
    assert!(s.fwd_loss(&bad_x, &good_y).is_err());
    // wrong y dtype
    let bad_y = HostTensor::i32(vec![n], vec![0; n]).unwrap();
    assert!(s.fwd_loss(&good_x, &bad_y).is_err());
    // wrong mask length
    assert!(s.train_step(&good_x, &good_y, &vec![1.0; n - 1], 0.1).is_err());
    // wrong grads arity for apply
    assert!(s.apply(&[], 0.1).is_err());
    // still usable after rejected calls
    assert!(s.fwd_loss(&good_x, &good_y).is_ok());
}

#[test]
fn uninitialized_session_refuses_to_run() {
    let Some(m) = manifest() else { return };
    let mut s = Session::new(&m, "linreg", Flavour::Jnp).unwrap();
    let n = m.batch;
    let x = HostTensor::f32(vec![n, 1], vec![0.0; n]).unwrap();
    let y = HostTensor::f32(vec![n], vec![0.0; n]).unwrap();
    let err = s.fwd_loss(&x, &y).unwrap_err().to_string();
    assert!(err.contains("init"), "err: {err}");
}

#[test]
fn init_is_deterministic_per_seed_across_sessions() {
    let Some(m) = manifest() else { return };
    let mut a = Session::new(&m, "mlp", Flavour::Jnp).unwrap();
    let mut b = Session::new(&m, "mlp", Flavour::Jnp).unwrap();
    a.init(123).unwrap();
    b.init(123).unwrap();
    let pa = a.params_to_host().unwrap();
    let pb = b.params_to_host().unwrap();
    for (x, y) in pa.iter().zip(&pb) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
    let mut c = Session::new(&m, "mlp", Flavour::Jnp).unwrap();
    c.init(124).unwrap();
    let pc = c.params_to_host().unwrap();
    assert!(pa
        .iter()
        .zip(&pc)
        .any(|(x, y)| x.as_f32().unwrap() != y.as_f32().unwrap()));
}

#[test]
fn eval_zero_mask_returns_zero_sums() {
    let Some(m) = manifest() else { return };
    let mut s = Session::new(&m, "mlp", Flavour::Jnp).unwrap();
    s.init(0).unwrap();
    let (x, y, _) = batch_for(&m, "mlp", 2);
    let (l, mt, c) = s.eval_batch(&x, &y, &vec![0.0; m.batch]).unwrap();
    assert_eq!((l, mt, c), (0.0, 0.0, 0.0));
}

#[test]
fn session_stats_count_executions() {
    let Some(m) = manifest() else { return };
    let mut s = Session::new(&m, "linreg", Flavour::Jnp).unwrap();
    s.init(0).unwrap();
    let (x, y, _) = batch_for(&m, "linreg", 3);
    let n0 = s.stats().executions;
    s.fwd_loss(&x, &y).unwrap();
    s.fwd_loss(&x, &y).unwrap();
    assert_eq!(s.stats().executions, n0 + 2);
    assert!(s.stats().compile_ns > 0);
}
