//! Wire-codec property tests: every frame survives
//! encode → decode → re-encode byte-identically over awkward payloads
//! (empty batches, single-row, non-finite losses, max-version stamps),
//! and every truncated or corrupted frame is rejected — never
//! panicking, never over-allocating, never silently mis-decoding.
//!
//! Byte-level comparison (rather than `PartialEq`) is deliberate: it
//! holds for NaN losses where equality would lie, and it is exactly the
//! property the sync-mode pipeline-equivalence guarantee needs — what a
//! worker computes is bit-for-bit what the leader selects on.

use std::io::Cursor;

use obftf::coordinator::proto::{
    self, read_frame, Frame, ViewRow, WorkerStats, MAX_FRAME_BYTES, NO_ID, PROTO_VERSION,
};
use obftf::data::tensor::{bf16_to_f32, f32_to_bf16};
use obftf::data::{HostTensor, TensorData};
use obftf::runtime::ScorePrecision;
use obftf::testkit::{cases, propcheck};

/// Encode, read back through the stream reader, re-encode, compare.
fn assert_roundtrip(frame: &Frame) {
    let bytes = frame.encode();
    let mut cur = Cursor::new(bytes.clone());
    let (back, used) = read_frame(&mut cur)
        .expect("well-formed frame decodes")
        .expect("frame present");
    assert_eq!(used, bytes.len(), "{}: wire size mismatch", frame.name());
    assert_eq!(back.encode(), bytes, "{}: re-encode differs", frame.name());
    // nothing left in the stream
    assert!(read_frame(&mut cur).expect("clean EOF").is_none());
}

#[test]
fn loss_records_roundtrip_over_awkward_payloads() {
    propcheck(
        "proto-loss-records-roundtrip",
        120,
        |rng| {
            let (ids, losses, stamp) = cases::wire_losses(rng);
            let seq = if rng.below(4) == 0 { u64::MAX } else { rng.below(1 << 30) as u64 };
            (seq, rng.below(64) as u32, stamp, ids, losses)
        },
        |(seq, worker, stamp, ids, losses)| {
            assert_roundtrip(&Frame::LossRecords {
                seq: *seq,
                worker: *worker,
                stamp: *stamp,
                ids: ids.clone(),
                losses: losses.clone(),
            });
            Ok(())
        },
    );
}

#[test]
fn score_batch_roundtrips_over_awkward_batches() {
    propcheck(
        "proto-score-batch-roundtrip",
        80,
        |rng| (rng.below(1 << 20) as u64, cases::wire_batch(rng)),
        |(seq, batch)| {
            assert_roundtrip(&Frame::ScoreBatch { seq: *seq, batch: batch.clone() });
            Ok(())
        },
    );
}

#[test]
fn cache_frames_roundtrip_with_max_version_stamps() {
    propcheck(
        "proto-cache-roundtrip",
        120,
        |rng| {
            let (ids, losses, stamp) = cases::wire_losses(rng);
            let lookup_ids: Vec<u64> = ids
                .iter()
                .map(|&id| if id % 7 == 0 { NO_ID } else { id })
                .collect();
            let rows: Vec<ViewRow> = losses
                .iter()
                .enumerate()
                .map(|(pos, &loss)| ViewRow { pos: pos as u32, loss, stamp })
                .collect();
            let now = if ids.len() % 2 == 0 { u64::MAX } else { stamp };
            (lookup_ids, rows, now, ids.len() % 3 == 0)
        },
        |(ids, rows, now, exact)| {
            assert_roundtrip(&Frame::CacheLookup {
                req: 3,
                now: *now,
                exact: *exact,
                ids: ids.clone(),
            });
            assert_roundtrip(&Frame::CacheView { req: 3, worker: 1, rows: rows.clone() });
            Ok(())
        },
    );
}

#[test]
fn param_update_and_stats_roundtrip() {
    let weights = vec![
        HostTensor::f32(vec![3, 2], vec![1.0, f32::NAN, -0.0, 2.5, f32::INFINITY, -7.0]).unwrap(),
        HostTensor::f32(vec![0], vec![]).unwrap(),
        HostTensor::i32(vec![2], vec![i32::MIN, i32::MAX]).unwrap(),
    ];
    assert_roundtrip(&Frame::ParamUpdate { version: u64::MAX, weights });
    assert_roundtrip(&Frame::Shutdown);
    assert_roundtrip(&Frame::WorkerStats(WorkerStats {
        worker: u32::MAX,
        scored_batches: u64::MAX,
        scored_rows: 0,
        recorded_rows: 1,
        lookups: 2,
    }));
}

/// The handshake frame every worker leads with: carries the protocol
/// version and the worker's id, survives the wire bit-exactly at the
/// extremes, and decodes back to the live PROTO_VERSION.
#[test]
fn hello_handshake_roundtrips() {
    for (proto, worker) in [(PROTO_VERSION, 0), (0, u32::MAX), (u32::MAX, 7)] {
        assert_roundtrip(&Frame::Hello { proto, worker });
    }
    let bytes = Frame::Hello { proto: PROTO_VERSION, worker: 3 }.encode();
    let (back, _) = read_frame(&mut Cursor::new(bytes)).unwrap().unwrap();
    match back {
        Frame::Hello { proto, worker } => {
            assert_eq!(proto, PROTO_VERSION);
            assert_eq!(worker, 3);
        }
        other => panic!("expected Hello, got {}", other.name()),
    }
}

/// Every strict prefix of a valid frame must be rejected (or report a
/// clean boundary EOF for the empty prefix) — a dropped pipe mid-frame
/// can never decode to a wrong frame.
#[test]
fn truncated_frames_are_rejected() {
    let mut rng = obftf::data::Rng::seed_from(0xf4a3);
    let (ids, losses, stamp) = cases::wire_losses(&mut rng);
    let frames = vec![
        Frame::Shutdown,
        Frame::LossRecords { seq: 1, worker: 0, stamp, ids, losses },
        Frame::ScoreBatch { seq: 2, batch: cases::wire_batch(&mut rng) },
        Frame::CacheLookup { req: 1, now: u64::MAX, exact: true, ids: vec![1, NO_ID] },
        Frame::CacheView {
            req: 1,
            worker: 0,
            rows: vec![ViewRow { pos: 0, loss: 0.5, stamp: u64::MAX }],
        },
        Frame::ParamUpdate {
            version: 0,
            weights: vec![HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap()],
        },
        Frame::WorkerStats(WorkerStats::default()),
    ];
    for frame in &frames {
        let bytes = frame.encode();
        let mut empty = Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).expect("boundary EOF is clean").is_none());
        for cut in 1..bytes.len() {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            assert!(
                read_frame(&mut cur).is_err(),
                "{}: prefix of {cut}/{} bytes must be rejected",
                frame.name(),
                bytes.len()
            );
        }
    }
}

/// Flipping the tag byte to garbage, or appending trailing payload
/// bytes, must be rejected too (the length prefix alone is not trusted).
#[test]
fn corrupted_frames_are_rejected() {
    let frame = Frame::CacheLookup { req: 1, now: 2, exact: false, ids: vec![3] };
    let bytes = frame.encode();
    // unknown tag
    let mut bad = bytes.clone();
    bad[4] = 250;
    assert!(read_frame(&mut Cursor::new(bad)).is_err());
    // bad bool byte
    let mut bad = bytes.clone();
    let bool_at = 4 + 1 + 8 + 8; // tag + req + now
    bad[bool_at] = 7;
    assert!(read_frame(&mut Cursor::new(bad)).is_err());
    // payload longer than the frame claims (trailing bytes in body)
    let mut body = bytes[4..].to_vec();
    body.push(0);
    assert!(Frame::decode(&body).is_err());
    // element count beyond the payload: patch the ids length field
    let mut bad = bytes;
    let len_at = 4 + 1 + 8 + 8 + 1; // tag + req + now + exact
    bad[len_at..len_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(read_frame(&mut Cursor::new(bad)).is_err());
}

/// The coalescing envelope: empty, single-member and multi-member
/// `Batch` frames survive the wire byte-identically, including every
/// strict prefix being rejected.
#[test]
fn batch_envelope_roundtrips_and_rejects_prefixes() {
    let empty = Frame::Batch(vec![]);
    let single = Frame::Batch(vec![Frame::CacheLookup {
        req: 1,
        now: 2,
        exact: true,
        ids: vec![0, NO_ID, 7],
    }]);
    let multi = Frame::Batch(vec![
        Frame::LossRecords {
            seq: u64::MAX,
            worker: 1,
            stamp: 3,
            ids: vec![4, 6],
            losses: vec![0.5, f32::NAN],
        },
        Frame::LossRecords { seq: u64::MAX, worker: 0, stamp: 3, ids: vec![], losses: vec![] },
        Frame::CacheLookup { req: 9, now: 3, exact: false, ids: vec![1, 2, 3] },
    ]);
    for env in [&empty, &single, &multi] {
        assert_roundtrip(env);
        let bytes = env.encode();
        for cut in 1..bytes.len() {
            let mut cur = Cursor::new(bytes[..cut].to_vec());
            assert!(
                read_frame(&mut cur).is_err(),
                "Batch prefix of {cut}/{} bytes must be rejected",
                bytes.len()
            );
        }
    }
    let back = {
        let bytes = multi.encode();
        let (f, _) = read_frame(&mut Cursor::new(bytes)).unwrap().unwrap();
        f
    };
    let Frame::Batch(members) = back else { panic!("expected Batch") };
    assert_eq!(members.len(), 3);
    assert!(matches!(&members[0], Frame::LossRecords { ids, .. } if ids == &vec![4, 6]));
    assert!(matches!(&members[2], Frame::CacheLookup { req: 9, .. }));
}

/// Envelope-level corruption: a nested `Batch` member, a corrupted
/// member tag, a member length overrunning the envelope, and an
/// overstated member count must each reject the *whole* frame —
/// a coalesced write is all-or-nothing.
#[test]
fn batch_envelope_corruption_rejects_the_whole_frame() {
    // nesting is unencodable through the public API (debug-asserted),
    // so hand-assemble an envelope whose member is itself an envelope
    let inner = Frame::Batch(vec![Frame::Shutdown]).encode();
    let mut body = vec![9u8]; // TAG_BATCH
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&((inner.len() - 4) as u32).to_le_bytes());
    body.extend_from_slice(&inner[4..]);
    let err = Frame::decode(&body).expect_err("nested envelope must be rejected");
    assert!(format!("{err:#}").contains("nested Batch"), "{err:#}");

    let env = Frame::Batch(vec![
        Frame::Shutdown,
        Frame::CacheLookup { req: 1, now: 2, exact: true, ids: vec![5] },
    ]);
    let enc = env.encode();
    // corrupt the second member's tag byte:
    // prefix(4) + tag(1) + count(8) + m0 len(4) + m0 body(1) + m1 len(4)
    let second_tag_at = 4 + 1 + 8 + 4 + 1 + 4;
    let mut bad = enc.clone();
    bad[second_tag_at] = 251;
    assert!(read_frame(&mut Cursor::new(bad)).is_err(), "bad member tag");
    // first member claims more bytes than the envelope holds
    let mut bad = enc.clone();
    bad[4 + 1 + 8] = 200;
    assert!(read_frame(&mut Cursor::new(bad)).is_err(), "member length overrun");
    // member count beyond the payload
    let mut bad = enc;
    bad[5..13].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(read_frame(&mut Cursor::new(bad)).is_err(), "overstated member count");
}

/// The bf16 param broadcast at the integration layer: the wire form is
/// strictly smaller than f32, decodes keep the bf16 dtype so re-encode
/// is byte-identical, expansion pins NaN quieting and exact ±Inf/−0.0,
/// and every strict prefix is rejected.
#[test]
fn bf16_param_update_shrinks_and_roundtrips_byte_identically() {
    let weights = vec![
        HostTensor::f32(
            vec![2, 3],
            vec![1.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 0.15625],
        )
        .unwrap(),
        HostTensor::i32(vec![2], vec![i32::MIN, 7]).unwrap(),
    ];
    let bf = proto::encode_param_update(3, &weights, ScorePrecision::Bf16);
    let f32_enc = proto::encode_param_update(3, &weights, ScorePrecision::F32);
    assert!(bf.len() < f32_enc.len(), "bf16 {} !< f32 {}", bf.len(), f32_enc.len());
    // the f32 tensor's payload halves: 6 elements save 12 bytes
    assert_eq!(f32_enc.len() - bf.len(), 12);

    let (back, used) = read_frame(&mut Cursor::new(bf.clone())).unwrap().unwrap();
    assert_eq!(used, bf.len());
    assert_eq!(back.encode(), bf, "bf16 broadcast must re-encode byte-identically");
    let Frame::ParamUpdate { version, weights: got } = back else {
        panic!("expected ParamUpdate")
    };
    assert_eq!(version, 3);
    assert!(matches!(got[0].data, TensorData::Bf16(_)), "wire dtype preserved");
    assert!(matches!(got[1].data, TensorData::I32(_)), "i32 passes through exact");
    let expanded = got[0].expand_to_f32();
    let v = expanded.as_f32().unwrap();
    assert_eq!(v[0].to_bits(), 1.0f32.to_bits());
    assert!(v[1].is_nan(), "NaN survives");
    assert_eq!(v[2], f32::INFINITY);
    assert_eq!(v[3], f32::NEG_INFINITY);
    assert_eq!(v[4].to_bits(), (-0.0f32).to_bits());
    // 0.15625 = 2^-3 + 2^-5 is exactly representable in bf16
    assert_eq!(v[5].to_bits(), 0.15625f32.to_bits());
    // the expansion is the canonical elementwise conversion
    assert_eq!(v[5].to_bits(), bf16_to_f32(f32_to_bf16(0.15625)).to_bits());

    for cut in 1..bf.len() {
        let mut cur = Cursor::new(bf[..cut].to_vec());
        assert!(
            read_frame(&mut cur).is_err(),
            "bf16 ParamUpdate prefix of {cut}/{} bytes must be rejected",
            bf.len()
        );
    }
}

/// The length prefix is capped: a corrupted (or hostile) header
/// claiming a frame beyond `MAX_FRAME_BYTES` is rejected *before* any
/// body allocation, an in-cap claim over a short stream reports a
/// truncated body rather than blocking, and a zero-length frame —
/// impossible to encode, every frame has a tag byte — is rejected too.
#[test]
fn implausible_frame_lengths_are_rejected_without_allocation() {
    // 4-byte header only: claims cap+1 bytes of body that don't exist.
    // read_frame must refuse on the header alone — if it tried to
    // allocate/read the claimed body this test would OOM or hang.
    let over = (MAX_FRAME_BYTES + 1) as u32;
    let mut cur = Cursor::new(over.to_le_bytes().to_vec());
    let err = read_frame(&mut cur).expect_err("over-cap length must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("implausible frame length"), "msg: {msg}");
    // u32::MAX length prefix: same refusal
    let mut cur = Cursor::new(u32::MAX.to_le_bytes().to_vec());
    assert!(read_frame(&mut cur).is_err());
    // in-cap claim, but the stream ends after 3 body bytes: truncation,
    // not a giant buffer
    let mut bytes = 1024u32.to_le_bytes().to_vec();
    bytes.extend_from_slice(&[8, 0, 0]);
    let err = read_frame(&mut Cursor::new(bytes)).expect_err("short body must be rejected");
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    // zero-length frame
    let mut cur = Cursor::new(0u32.to_le_bytes().to_vec());
    assert!(read_frame(&mut cur).is_err(), "zero-length frame must be rejected");
}
