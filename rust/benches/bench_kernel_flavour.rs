//! `abl-kernel` (DESIGN.md §4): execution-flavour comparison.
//!
//! With AOT artifacts built this compares the pallas flavour
//! (interpret-mode L1 kernels) against jnp (XLA-native fusion); on a
//! fresh checkout it measures the pure-Rust native backend (blocked
//! kernels at the `OBFTF_NATIVE_THREADS`/`OBFTF_NATIVE_KERNELS`
//! configuration). On a real TPU the pallas path would use the MXU
//! directly; on this CPU substrate the gap quantifies the cost of
//! interpret-mode fidelity (EXPERIMENTS.md §Perf). Dense-chain cases
//! report GFLOP/s and rows/s alongside latency.

use obftf::data::{HostTensor, Rng};
use obftf::runtime::kernels::{dense_fwd_flops, dense_train_flops};
use obftf::runtime::{Manifest, Session};
use obftf::util::benchkit::{black_box, Bench};

fn main() {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).unwrap();
    let mut bench = Bench::heavy();
    let n = manifest.batch;

    for model in ["linreg", "mlp"] {
        let Ok(entry) = manifest.model(model) else {
            eprintln!("skipping {model}: not in manifest");
            continue;
        };
        let stride: usize = entry.x_shape.iter().product();
        let mut rng = Rng::seed_from(3);
        let mut shape = vec![n];
        shape.extend_from_slice(&entry.x_shape);
        let x = HostTensor::f32(
            shape,
            (0..n * stride).map(|_| rng.normal() as f32 * 0.4).collect(),
        )
        .unwrap();
        let y = if entry.is_classification() {
            HostTensor::i32(
                vec![n],
                (0..n).map(|_| rng.below(entry.num_classes) as i32).collect(),
            )
            .unwrap()
        } else {
            HostTensor::f32(vec![n], (0..n).map(|_| rng.normal() as f32).collect())
                .unwrap()
        };
        let mask: Vec<f32> = (0..n).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
        // conv models have no dense-chain FLOP model; report latency only
        let (fwd_flops, train_flops) = match entry.dense_dims() {
            Some(dims) => (dense_fwd_flops(&dims, n), dense_train_flops(&dims, n)),
            None => (0.0, 0.0),
        };

        for flavour in entry.flavours() {
            let mut s = match Session::new(&manifest, model, flavour) {
                Ok(s) => s,
                Err(e) => {
                    // artifact flavours need the pjrt cargo feature
                    eprintln!("skipping {model}/{flavour}: {e}");
                    continue;
                }
            };
            s.init(1).unwrap();
            bench.run_throughput(
                &format!("fwd_loss/{model}/{}", flavour.as_str()),
                fwd_flops,
                n as f64,
                || {
                    black_box(s.fwd_loss(&x, &y).unwrap());
                },
            );
            bench.run_throughput(
                &format!("train_step/{model}/{}", flavour.as_str()),
                train_flops,
                n as f64,
                || {
                    black_box(s.train_step(&x, &y, &mask, 0.01).unwrap());
                },
            );
        }
    }
    bench
        .finish("execution flavour: native vs pallas vs jnp", "BENCH_kernel_flavour.json")
        .unwrap();
}
