//! `abl-kernel` (DESIGN.md §4): pallas vs jnp artifact flavour.
//!
//! The pallas flavour lowers interpret-mode Pallas kernels (scalarized
//! HLO while-loops on CPU — the faithful L1 structure); the jnp flavour
//! lets XLA fuse natively. On a real TPU the pallas path would use the
//! MXU directly; on this CPU substrate the gap quantifies the cost of
//! interpret-mode fidelity (EXPERIMENTS.md §Perf).

use obftf::data::{HostTensor, Rng};
use obftf::runtime::{Flavour, Manifest, Session};
use obftf::util::benchkit::{black_box, Bench};

fn main() {
    let dir = obftf::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping bench_kernel_flavour: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let mut bench = Bench::heavy();
    let n = manifest.batch;

    for model in ["linreg", "mlp"] {
        let entry = manifest.model(model).unwrap();
        let stride: usize = entry.x_shape.iter().product();
        let mut rng = Rng::seed_from(3);
        let mut shape = vec![n];
        shape.extend_from_slice(&entry.x_shape);
        let x = HostTensor::f32(
            shape,
            (0..n * stride).map(|_| rng.normal() as f32 * 0.4).collect(),
        )
        .unwrap();
        let y = if entry.is_classification() {
            HostTensor::i32(
                vec![n],
                (0..n).map(|_| rng.below(entry.num_classes) as i32).collect(),
            )
            .unwrap()
        } else {
            HostTensor::f32(vec![n], (0..n).map(|_| rng.normal() as f32).collect())
                .unwrap()
        };
        let mask: Vec<f32> = (0..n).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();

        for flavour in [Flavour::Jnp, Flavour::Pallas] {
            let mut s = Session::new(&manifest, model, flavour).unwrap();
            s.init(1).unwrap();
            bench.run(&format!("fwd_loss/{model}/{}", flavour.as_str()), || {
                black_box(s.fwd_loss(&x, &y).unwrap());
            });
            bench.run(&format!("train_step/{model}/{}", flavour.as_str()), || {
                black_box(s.train_step(&x, &y, &mask, 0.01).unwrap());
            });
        }
    }
    println!("{}", bench.table("kernel flavour: pallas (interpret) vs jnp (XLA-fused)"));
}
