//! `fig1` throughput harness: end-to-end Algorithm-1 step latency on
//! the linear-regression workload, per selection method. Regenerates
//! the compute side of Fig 1 (the accuracy side is
//! `examples/fig1_regression.rs`).

use obftf::config::TrainConfig;
use obftf::coordinator::Trainer;
use obftf::data::BatchIter;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::util::benchkit::Bench;

fn main() {
    let dir = obftf::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping bench_fig1: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let mut bench = Bench::new();

    for method in [
        Method::Uniform,
        Method::SelectiveBackprop,
        Method::MinK,
        Method::Obftf,
        Method::ObftfProx,
        Method::FrankWolfe,
    ] {
        let cfg = TrainConfig {
            model: "linreg".into(),
            method,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.01,
            n_train: Some(512),
            n_test: Some(128),
            ..Default::default()
        };
        let mut t = Trainer::with_manifest(&cfg, &manifest).unwrap();
        let (train, _) = obftf::coordinator::trainer::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, manifest.batch, None).collect();
        let mut i = 0;
        bench.run(&format!("fig1-step/{}", method.as_str()), || {
            t.step_batch(&batches[i % batches.len()]).unwrap();
            i += 1;
        });
    }
    println!("{}", bench.table("fig1: linreg end-to-end step (fwd + select + bwd)"));
}
