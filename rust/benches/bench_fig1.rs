//! `fig1` throughput harness: end-to-end Algorithm-1 step latency on
//! the linear-regression workload, per selection method. Regenerates
//! the compute side of Fig 1 (the accuracy side is
//! `examples/fig1_regression.rs`). Runs on the manifest's default
//! flavour (native when no artifacts are built).

use obftf::config::TrainConfig;
use obftf::coordinator::Trainer;
use obftf::data::BatchIter;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::util::benchkit::Bench;

fn main() {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).unwrap();
    let mut bench = Bench::new();

    for method in [
        Method::Uniform,
        Method::SelectiveBackprop,
        Method::MinK,
        Method::Obftf,
        Method::ObftfProx,
        Method::FrankWolfe,
    ] {
        let cfg = TrainConfig {
            model: "linreg".into(),
            method,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.01,
            n_train: Some(512),
            n_test: Some(128),
            ..Default::default()
        };
        let mut t = Trainer::with_manifest(&cfg, &manifest).unwrap();
        let (train, _) = obftf::coordinator::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, manifest.batch, None).collect();
        let mut i = 0;
        bench.run(&format!("fig1-step/{}", method.as_str()), || {
            t.step_batch(&batches[i % batches.len()]).unwrap();
            i += 1;
        });
    }
    bench
        .finish("fig1: linreg end-to-end step (fwd + select + bwd)", "BENCH_fig1.json")
        .unwrap();
}
