//! `fig2` throughput harness: MLP (MNIST-role) step latency per method
//! and per sampling ratio, plus the phase breakdown the paper's cost
//! model assumes (forward vs selection vs backward).

use obftf::config::TrainConfig;
use obftf::coordinator::Trainer;
use obftf::data::BatchIter;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::util::benchkit::Bench;

fn main() {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).unwrap();
    let mut bench = Bench::heavy();

    // per-method step cost at the paper's ratio band
    for method in [Method::Uniform, Method::MinK, Method::Obftf, Method::ObftfProx] {
        for ratio in [0.1, 0.5] {
            let cfg = TrainConfig {
                model: "mlp".into(),
                method,
                sampling_ratio: ratio,
                epochs: 1,
                lr: 0.1,
                n_train: Some(1024),
                n_test: Some(128),
                ..Default::default()
            };
            let mut t = Trainer::with_manifest(&cfg, &manifest).unwrap();
            let (train, _) =
                obftf::coordinator::build_datasets(&cfg).unwrap();
            let batches: Vec<_> = BatchIter::new(&train, manifest.batch, None).collect();
            let mut i = 0;
            bench.run(
                &format!("fig2-step/{}/r{:.2}", method.as_str(), ratio),
                || {
                    t.step_batch(&batches[i % batches.len()]).unwrap();
                    i += 1;
                },
            );
        }
    }
    bench.finish("fig2: mlp end-to-end step", "BENCH_fig2.json").unwrap();
}
