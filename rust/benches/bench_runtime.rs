//! Runtime-layer micro-benches: the plumbing between the coordinator
//! and the execution backend — single-exec latency, the engine's
//! channel round-trip, prefetcher throughput, and checkpoint
//! serialization — plus the native kernel-subsystem comparison rows
//! (naive PR-1 loops vs blocked kernels, single- and multi-threaded)
//! that seed the repo-root `BENCH_native_kernels.json` perf
//! trajectory. (Host↔literal conversion is additionally measured when
//! the `pjrt` feature is on.)

use obftf::checkpoint::Checkpoint;
use obftf::data::stream::{Prefetcher, ResamplingStream};
use obftf::data::HostTensor;
use obftf::runtime::kernels::{dense_fwd_flops, dense_train_flops, simd_available};
use obftf::runtime::{
    Backend, Engine, KernelConfig, Manifest, NativeBackend, ScorePrecision, Session,
};
use obftf::testkit::TempDir;
use obftf::util::benchkit::{black_box, Bench};

fn main() {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).unwrap();
    let flavour = manifest.default_flavour();
    let mut bench = Bench::new();
    let n = manifest.batch;

    // native kernel throughput: blocked/threaded kernels vs the naive
    // loops they replaced, at the paper's mlp × batch-128 workload
    if let Some((entry, dims)) = manifest
        .model("mlp")
        .ok()
        .and_then(|e| e.dense_dims().map(|d| (e, d)))
    {
        let mut rng = obftf::data::Rng::seed_from(17);
        let x = HostTensor::f32(
            vec![n, dims[0]],
            (0..n * dims[0]).map(|_| rng.normal() as f32 * 0.3).collect(),
        )
        .unwrap();
        let classes = *dims.last().unwrap();
        let y = HostTensor::i32(
            vec![n],
            (0..n).map(|_| rng.below(classes) as i32).collect(),
        )
        .unwrap();
        let mask = vec![1.0f32; n];
        let fwd_flops = dense_fwd_flops(&dims, n);
        let train_flops = dense_train_flops(&dims, n);
        let threads = KernelConfig::from_env().threads;
        let mut cases = vec![
            ("naive".to_string(), KernelConfig::reference()),
            ("blocked-t1".to_string(), KernelConfig::blocked(1)),
        ];
        if threads > 1 {
            cases.push((format!("blocked-t{threads}"), KernelConfig::blocked(threads)));
        }
        if simd_available() {
            cases.push(("simd-t1".to_string(), KernelConfig::simd(1)));
            if threads > 1 {
                cases.push((format!("simd-t{threads}"), KernelConfig::simd(threads)));
            }
        }
        for (tag, kcfg) in cases {
            let mut b = NativeBackend::with_kernel_config("mlp", entry, n, kcfg).unwrap();
            b.init(1).unwrap();
            bench.run_throughput(
                &format!("native/mlp/fwd_loss/{tag}"),
                fwd_flops,
                n as f64,
                || {
                    black_box(b.fwd_loss(&x, &y).unwrap());
                },
            );
            bench.run_throughput(
                &format!("native/mlp/train_step/{tag}"),
                train_flops,
                n as f64,
                || {
                    black_box(b.train_step(&x, &y, &mask, 0.01).unwrap());
                },
            );
        }

        // fast-scoring row: the fleet's bf16-panel forward on the same
        // workload (rows/s is the number the async pipeline cares about)
        if simd_available() {
            let kcfg = KernelConfig::simd(1);
            let mut b = NativeBackend::with_kernel_config("mlp", entry, n, kcfg).unwrap();
            b.init(1).unwrap();
            b.set_score_precision(ScorePrecision::Bf16);
            bench.run_throughput("native/mlp/fwd_loss/bf16-score", fwd_flops, n as f64, || {
                black_box(b.fwd_loss(&x, &y).unwrap());
            });
        }
    }

    // host tensor -> literal -> host tensor conversion cost (784-wide
    // batch), PJRT builds only
    #[cfg(feature = "pjrt")]
    {
        use obftf::runtime::{from_literal, to_literal};
        let mut rng = obftf::data::Rng::seed_from(11);
        let t = HostTensor::f32(
            vec![n, 784],
            (0..n * 784).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        if let Ok(lit) = to_literal(&t) {
            bench.run("to_literal/128x784", || {
                black_box(to_literal(&t).unwrap());
            });
            bench.run("from_literal/128x784", || {
                black_box(from_literal(&lit).unwrap());
            });
        }
    }

    // single-executable latency floor (linreg = smallest model)
    let mut s = Session::new(&manifest, "linreg", flavour).unwrap();
    s.init(0).unwrap();
    let x = HostTensor::f32(vec![n, 1], (0..n).map(|i| i as f32 / n as f32).collect())
        .unwrap();
    let y = HostTensor::f32(vec![n], vec![0.5; n]).unwrap();
    bench.run("exec/linreg/fwd_loss", || {
        black_box(s.fwd_loss(&x, &y).unwrap());
    });

    // engine round-trip overhead: same op through the worker channel
    let engine = Engine::new(&manifest, "linreg", flavour, 1).unwrap();
    engine.init_broadcast(0).unwrap();
    bench.run("engine/roundtrip/fwd_loss", || {
        black_box(
            engine
                .fwd_loss_sharded(vec![(x.clone(), y.clone())])
                .unwrap(),
        );
    });

    // prefetcher throughput (mnist-proxy batches)
    let spec = obftf::data::mnist_proxy::MnistProxySpec {
        n_train: 2048,
        n_test: 16,
        ..Default::default()
    };
    let (train, _) = spec.build(5);
    let pf = Prefetcher::spawn(Box::new(ResamplingStream::new(train, 9, 0.0)), n, 4);
    bench.run("prefetch/mnist_batch", || {
        black_box(pf.next());
    });

    // checkpoint save/load (mlp-sized params)
    let mut ms = Session::new(&manifest, "mlp", flavour).unwrap();
    ms.init(0).unwrap();
    let params = ms.params_to_host().unwrap();
    let named: Vec<(String, HostTensor)> = manifest
        .model("mlp")
        .unwrap()
        .params
        .iter()
        .map(|p| p.name.clone())
        .zip(params)
        .collect();
    let ck = Checkpoint { step: 1, epoch: 1, params: named };
    let tmp = TempDir::new("bench-ck").unwrap();
    let path = tmp.file("mlp.ck");
    bench.run("checkpoint/save/mlp", || {
        ck.save(&path).unwrap();
    });
    bench.run("checkpoint/load/mlp", || {
        black_box(Checkpoint::load(&path).unwrap());
    });

    bench
        .finish("runtime: native kernels + plumbing", "BENCH_native_kernels.json")
        .unwrap();
}
