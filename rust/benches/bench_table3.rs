//! `table3` throughput harness: CNN (ResNet50-role) and CNN-lite
//! (MobileNetV2-role) step latency — the paper's "higher accuracy vs
//! higher computational efficiency" model pairing, measured on this
//! substrate. Also benches the sharded data-parallel step (the paper's
//! 32-GPU sync setup, scaled to worker threads).

use obftf::config::TrainConfig;
use obftf::coordinator::{ParallelTrainer, Trainer};
use obftf::data::BatchIter;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::util::benchkit::Bench;

fn main() {
    let dir = obftf::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping bench_table3: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let mut bench = Bench::heavy();

    for model in ["cnn", "cnn_lite"] {
        let cfg = TrainConfig {
            model: model.into(),
            method: Method::Obftf,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.05,
            n_train: Some(512),
            n_test: Some(128),
            ..Default::default()
        };
        let (train, _) = obftf::coordinator::trainer::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, manifest.batch, None).collect();

        let mut t = Trainer::with_manifest(&cfg, &manifest).unwrap();
        let mut i = 0;
        bench.run(&format!("table3-step/{model}/serial"), || {
            t.step_batch(&batches[i % batches.len()]).unwrap();
            i += 1;
        });

        // data-parallel variant (leader/worker over threads)
        let mut pcfg = cfg.clone();
        pcfg.workers = 2;
        let mut pt = ParallelTrainer::with_manifest(&pcfg, &manifest).unwrap();
        let mut j = 0;
        bench.run(&format!("table3-step/{model}/workers2"), || {
            pt.step_batch(&batches[j % batches.len()]).unwrap();
            j += 1;
        });
    }
    println!("{}", bench.table("table3: cnn / cnn_lite end-to-end step"));
}
