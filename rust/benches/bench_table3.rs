//! `table3` throughput harness: CNN (ResNet50-role) and CNN-lite
//! (MobileNetV2-role) step latency — the paper's "higher accuracy vs
//! higher computational efficiency" model pairing, measured on this
//! substrate. Runs hermetically on the native conv backend (no
//! artifacts needed): per model it times the raw "ten forward" pass
//! (exact conv GFLOP/s from the manifest geometry), the serial
//! Algorithm-1 step, and the sharded data-parallel step (the paper's
//! 32-GPU sync setup, scaled to worker threads).

use obftf::config::TrainConfig;
use obftf::coordinator::{ParallelTrainer, Trainer};
use obftf::data::BatchIter;
use obftf::runtime::kernels::{conv_fwd_flops, conv_train_flops};
use obftf::runtime::{Manifest, Session};
use obftf::sampling::{budget_for, Method};
use obftf::util::benchkit::Bench;

fn main() {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).unwrap();
    let mut bench = Bench::heavy();
    let batch = manifest.batch;

    for model in ["cnn", "cnn_lite"] {
        let cfg = TrainConfig {
            model: model.into(),
            method: Method::Obftf,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.05,
            n_train: Some(512),
            n_test: Some(128),
            ..Default::default()
        };
        // conv models run natively when the manifest carries their
        // stride schedule; artifact manifests without the pjrt feature
        // still skip
        let mut t = match Trainer::with_manifest(&cfg, &manifest) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e:#}");
                continue;
            }
        };
        let (train, _) = obftf::coordinator::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, batch, None).collect();

        // exact conv FLOP accounting from the manifest geometry: the
        // Algorithm-1 step is a full-batch "ten forward" plus a
        // gathered train step over the b selected rows
        let entry = manifest.model(model).unwrap();
        let (fwd_flops, step_flops) = entry
            .conv_chain()
            .map(|(shapes, head)| {
                let fwd = conv_fwd_flops(&shapes, head, batch);
                let b = budget_for(cfg.sampling_ratio, batch);
                (fwd, fwd + conv_train_flops(&shapes, head, b))
            })
            .unwrap_or((0.0, 0.0));
        let flavour = manifest.default_flavour();
        if let Ok(mut session) = Session::new(&manifest, model, flavour) {
            session.init(7).unwrap();
            let mut i = 0;
            bench.run_throughput(&format!("table3-fwd/{model}"), fwd_flops, batch as f64, || {
                let b = &batches[i % batches.len()];
                session.fwd_loss(&b.x, &b.y).unwrap();
                i += 1;
            });
        }

        let mut i = 0;
        bench.run_throughput(
            &format!("table3-step/{model}/serial"),
            step_flops,
            batch as f64,
            || {
                t.step_batch(&batches[i % batches.len()]).unwrap();
                i += 1;
            },
        );

        // data-parallel variant (leader/worker over threads); its
        // workers run the masked full-batch backward over shards, so
        // the gathered-step FLOP model does not apply — rows/s only
        let mut pcfg = cfg.clone();
        pcfg.workers = 2;
        let mut pt = ParallelTrainer::with_manifest(&pcfg, &manifest).unwrap();
        let mut j = 0;
        bench.run_throughput(
            &format!("table3-step/{model}/workers2"),
            0.0,
            batch as f64,
            || {
                pt.step_batch(&batches[j % batches.len()]).unwrap();
                j += 1;
            },
        );
    }
    // the data-parallel shape is model-independent; fall back to the
    // mlp so the sharded step is still measured if conv cannot run
    if bench.results().is_empty() && manifest.model("mlp").is_ok() {
        let cfg = TrainConfig {
            model: "mlp".into(),
            method: Method::Obftf,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.05,
            n_train: Some(512),
            n_test: Some(128),
            workers: 2,
            ..Default::default()
        };
        let (train, _) = obftf::coordinator::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, batch, None).collect();
        let mut pt = ParallelTrainer::with_manifest(&cfg, &manifest).unwrap();
        let mut j = 0;
        bench.run("table3-step/mlp/workers2", || {
            pt.step_batch(&batches[j % batches.len()]).unwrap();
            j += 1;
        });
    }

    bench
        .finish("table3: cnn / cnn_lite end-to-end step", "BENCH_table3.json")
        .unwrap();
}
