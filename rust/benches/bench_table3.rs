//! `table3` throughput harness: CNN (ResNet50-role) and CNN-lite
//! (MobileNetV2-role) step latency — the paper's "higher accuracy vs
//! higher computational efficiency" model pairing, measured on this
//! substrate. Also benches the sharded data-parallel step (the paper's
//! 32-GPU sync setup, scaled to worker threads).

use obftf::config::TrainConfig;
use obftf::coordinator::{ParallelTrainer, Trainer};
use obftf::data::BatchIter;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::util::benchkit::Bench;

fn main() {
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).unwrap();
    let mut bench = Bench::heavy();

    for model in ["cnn", "cnn_lite"] {
        let cfg = TrainConfig {
            model: model.into(),
            method: Method::Obftf,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.05,
            n_train: Some(512),
            n_test: Some(128),
            ..Default::default()
        };
        // conv models need executable AOT artifacts; skip when the
        // current build can't run them (no native dense-chain form)
        let mut t = match Trainer::with_manifest(&cfg, &manifest) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e:#}");
                continue;
            }
        };
        let (train, _) = obftf::coordinator::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, manifest.batch, None).collect();

        let mut i = 0;
        bench.run(&format!("table3-step/{model}/serial"), || {
            t.step_batch(&batches[i % batches.len()]).unwrap();
            i += 1;
        });

        // data-parallel variant (leader/worker over threads)
        let mut pcfg = cfg.clone();
        pcfg.workers = 2;
        let mut pt = ParallelTrainer::with_manifest(&pcfg, &manifest).unwrap();
        let mut j = 0;
        bench.run(&format!("table3-step/{model}/workers2"), || {
            pt.step_batch(&batches[j % batches.len()]).unwrap();
            j += 1;
        });
    }
    // the data-parallel shape is model-independent; fall back to the
    // mlp so the sharded step is still measured without artifacts
    if bench.results().is_empty() && manifest.model("mlp").is_ok() {
        let cfg = TrainConfig {
            model: "mlp".into(),
            method: Method::Obftf,
            sampling_ratio: 0.25,
            epochs: 1,
            lr: 0.05,
            n_train: Some(512),
            n_test: Some(128),
            workers: 2,
            ..Default::default()
        };
        let (train, _) = obftf::coordinator::build_datasets(&cfg).unwrap();
        let batches: Vec<_> = BatchIter::new(&train, manifest.batch, None).collect();
        let mut pt = ParallelTrainer::with_manifest(&cfg, &manifest).unwrap();
        let mut j = 0;
        bench.run("table3-step/mlp/workers2", || {
            pt.step_batch(&batches[j % batches.len()]).unwrap();
            j += 1;
        });
    }

    bench
        .finish("table3: cnn / cnn_lite end-to-end step", "BENCH_table3.json")
        .unwrap();
}
