//! `sel-micro` (DESIGN.md §4): selection-policy latency vs batch size
//! and budget. The L3 perf target: OBFTF's solver must cost less than
//! one fwd_loss execution at n = 128 (see EXPERIMENTS.md §Perf).
//!
//! **Pipeline mode** (`OBFTF_BENCH_PIPELINE=1`): instead of the policy
//! micro-bench, run the staged continuous-training pipeline against the
//! serial streaming trainer on the same mlp workload and emit
//! `BENCH_pipeline.json` with steps/s, the cache hit-rate and the
//! async-eval stall. `OBFTF_PIPELINE_WORKERS` sets the fleet size (CI
//! sweeps 1 and 4); `OBFTF_BENCH_PIPELINE_STEPS` the steps per run.
//! Each invocation also runs the **multi-process** fleet (`proc-w1` and
//! `proc-wN` rows: `obftf worker` children over pipes, distributed
//! shard ownership) so one JSON carries thread and proc rows from the
//! same run, including wire traffic as `frame_bytes_per_step` plus the
//! pooled-codec split (`frames_per_step`, `encode_ns_per_step` and
//! per-frame-type bytes). A `socket-wN-bf16` row re-runs the socket
//! fleet with `param_precision = bf16` so the broadcast saving is
//! measurable against its f32 twin, a `socket-wN-overlap` row re-runs
//! it with the overlapped leader (lookup prefetch + parallel publish
//! fan-out) annotated with the hidden lookup/publish latencies and the
//! p50/p99 selection-to-apply, and a final `socket-reshard` row
//! drives one mid-run worker join plus one permanent leave (retired on
//! a spent restart budget) to price the elastic ownership transitions,
//! annotating the `reshards` count.
//!
//! CI smoke: set `OBFTF_BENCH_BUDGET_MS` / `OBFTF_BENCH_MAX_ITERS` for
//! a tiny run and `OBFTF_BENCH_JSON` to capture the summary artifact.

use obftf::config::TrainConfig;
use obftf::coordinator::{PipelineTrainer, StreamingTrainer, WireStats};
use obftf::data::rng::Rng;
use obftf::runtime::Manifest;
use obftf::sampling::{budget_for, Method};
use obftf::util::benchkit::{black_box, Bench};

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

/// Attach the leader's wire-path counters to the last bench row:
/// frames and encode time per step, plus the per-frame-type byte split
/// (param broadcast / score handoff / routed records / cache lookups /
/// coalesced envelopes) so a wire-tax regression names its frame type.
fn annotate_wire(bench: &mut Bench, wire: &WireStats, steps: usize) {
    let per = |v: u64| v as f64 / steps as f64;
    bench.annotate_last("frames_per_step", per(wire.frames));
    bench.annotate_last("encode_ns_per_step", per(wire.encode_ns));
    bench.annotate_last("param_bytes_per_step", per(wire.param_bytes));
    bench.annotate_last("score_bytes_per_step", per(wire.score_bytes));
    bench.annotate_last("route_bytes_per_step", per(wire.route_bytes));
    bench.annotate_last("lookup_bytes_per_step", per(wire.lookup_bytes));
    bench.annotate_last("envelope_bytes_per_step", per(wire.envelope_bytes));
}

/// The shared streaming workload both drivers run: mlp on the mnist
/// proxy, cheap deterministic selection (mink) so the measured contrast
/// is the stage overlap, eval cadence on so the serial baseline pays
/// its eval stalls on the hot path the way the pipeline does not.
fn workload(steps: usize) -> TrainConfig {
    TrainConfig {
        model: "mlp".to_string(),
        method: Method::MinK,
        sampling_ratio: 0.25,
        epochs: 0,
        stream_steps: steps,
        lr: 0.05,
        n_train: Some(2048),
        n_test: Some(512),
        seed: 23,
        eval_every: 4,
        prefetch_depth: 4,
        ..Default::default()
    }
}

fn pipeline_bench() {
    let mut bench = Bench::heavy();
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir()).expect("manifest loads");
    let steps = env_usize("OBFTF_BENCH_PIPELINE_STEPS").unwrap_or(48);
    let workers = env_usize("OBFTF_PIPELINE_WORKERS").unwrap_or(4);
    let cfg = workload(steps);

    bench.run_throughput("pipeline/serial-streaming/mlp", 0.0, steps as f64, || {
        let mut st = StreamingTrainer::with_manifest(&cfg, &manifest).expect("serial trainer");
        black_box(st.run().expect("serial run"));
    });

    let mut pcfg = cfg.clone();
    pcfg.pipeline = true;
    pcfg.pipeline_workers = workers;
    let mut hit_rate = 0.0f64;
    let mut stall_ms = 0.0f64;
    let mut fleet_fwd = 0.0f64;
    bench.run_throughput(
        &format!("pipeline/staged-w{workers}/mlp"),
        0.0,
        steps as f64,
        || {
            let mut p = PipelineTrainer::with_manifest(&pcfg, &manifest).expect("pipeline");
            black_box(p.run().expect("pipeline run"));
            hit_rate = p.cache_stats().hit_rate();
            stall_ms = p.eval_stall_ms() as f64;
            fleet_fwd = p.budget.inference_forwards as f64;
        },
    );
    bench.annotate_last("inference_workers", workers as f64);
    bench.annotate_last("cache_hit_rate", hit_rate);
    bench.annotate_last("eval_stall_ms", stall_ms);
    bench.annotate_last("inference_forwards", fleet_fwd);

    // multi-process fleet rows: the same workload over the child-
    // process transports — pipes (`proc-w*`) and Unix sockets
    // (`socket-w*`) — at one worker and at the sweep's fleet size, so
    // the thread-vs-proc contrast (stage overlap vs serialization tax)
    // and the pipe-vs-socket wire tax land in one JSON
    std::env::set_var("OBFTF_WORKER_BIN", env!("CARGO_BIN_EXE_obftf"));
    let mut fleet_sizes = vec![1usize];
    if workers != 1 {
        fleet_sizes.push(workers);
    }
    for (tag, socket) in [("proc", "pipes"), ("socket", "unix")] {
        // the env override beats the config inside
        // `PipelineOptions::resolve` — pin both knobs per row so the
        // proc-w1 row really runs one pipe worker even when CI sweeps
        // OBFTF_PIPELINE_WORKERS=4 or sets OBFTF_PIPELINE_SOCKET
        std::env::set_var("OBFTF_PIPELINE_SOCKET", socket);
        for &pw in &fleet_sizes {
            let mut ccfg = cfg.clone();
            ccfg.pipeline = true;
            ccfg.pipeline_proc = true;
            if socket != "pipes" {
                ccfg.pipeline_socket = socket.to_string();
            }
            ccfg.pipeline_workers = pw;
            std::env::set_var("OBFTF_PIPELINE_WORKERS", pw.to_string());
            let mut hit_rate = 0.0f64;
            let mut stall_ms = 0.0f64;
            let mut fleet_fwd = 0.0f64;
            let mut frame_bytes = 0.0f64;
            let mut wire = WireStats::default();
            bench.run_throughput(&format!("pipeline/{tag}-w{pw}/mlp"), 0.0, steps as f64, || {
                let mut p =
                    PipelineTrainer::with_manifest(&ccfg, &manifest).expect("fleet pipeline");
                black_box(p.run().expect("fleet pipeline run"));
                hit_rate = p.cache_stats().hit_rate();
                stall_ms = p.eval_stall_ms() as f64;
                fleet_fwd = p.budget.inference_forwards as f64;
                frame_bytes = p.frame_bytes() as f64;
                wire = p.wire_stats();
            });
            bench.annotate_last("inference_workers", pw as f64);
            bench.annotate_last("cache_hit_rate", hit_rate);
            bench.annotate_last("eval_stall_ms", stall_ms);
            bench.annotate_last("inference_forwards", fleet_fwd);
            bench.annotate_last("frame_bytes_per_step", frame_bytes / steps as f64);
            annotate_wire(&mut bench, &wire, steps);
        }
    }

    // bf16 param-broadcast row: the socket fleet at the sweep size with
    // the weight snapshot shipped in bf16 (`socket-wN-bf16`) — compare
    // frame_bytes_per_step against the f32 `socket-wN` row above for
    // the broadcast wire-tax saving
    {
        let pw = *fleet_sizes.last().unwrap();
        std::env::set_var("OBFTF_PIPELINE_SOCKET", "unix");
        std::env::set_var("OBFTF_PIPELINE_WORKERS", pw.to_string());
        std::env::set_var("OBFTF_PARAM_PRECISION", "bf16");
        let mut bcfg = cfg.clone();
        bcfg.pipeline = true;
        bcfg.pipeline_proc = true;
        bcfg.pipeline_socket = "unix".to_string();
        bcfg.pipeline_workers = pw;
        bcfg.param_precision = "bf16".to_string();
        let mut hit_rate = 0.0f64;
        let mut stall_ms = 0.0f64;
        let mut fleet_fwd = 0.0f64;
        let mut frame_bytes = 0.0f64;
        let mut wire = WireStats::default();
        bench.run_throughput(
            &format!("pipeline/socket-w{pw}-bf16/mlp"),
            0.0,
            steps as f64,
            || {
                let mut p =
                    PipelineTrainer::with_manifest(&bcfg, &manifest).expect("bf16 pipeline");
                black_box(p.run().expect("bf16 pipeline run"));
                hit_rate = p.cache_stats().hit_rate();
                stall_ms = p.eval_stall_ms() as f64;
                fleet_fwd = p.budget.inference_forwards as f64;
                frame_bytes = p.frame_bytes() as f64;
                wire = p.wire_stats();
            },
        );
        bench.annotate_last("inference_workers", pw as f64);
        bench.annotate_last("cache_hit_rate", hit_rate);
        bench.annotate_last("eval_stall_ms", stall_ms);
        bench.annotate_last("inference_forwards", fleet_fwd);
        bench.annotate_last("frame_bytes_per_step", frame_bytes / steps as f64);
        annotate_wire(&mut bench, &wire, steps);
        std::env::remove_var("OBFTF_PARAM_PRECISION");
    }

    // overlapped-leader row: the socket fleet at the sweep size with
    // `pipeline_overlap` on (`socket-wN-overlap`) — prefetched lookups,
    // parallel publish fan-out and the off-critical-path recorder
    // stage. Compare steps/s against the serial-schedule `socket-wN`
    // row above; the latencies the overlap hides land as
    // lookup_rtt_us / publish_us means plus the p50/p99
    // selection-to-apply the knob is supposed to shrink
    {
        let pw = *fleet_sizes.last().unwrap();
        std::env::set_var("OBFTF_PIPELINE_SOCKET", "unix");
        std::env::set_var("OBFTF_PIPELINE_WORKERS", pw.to_string());
        std::env::set_var("OBFTF_PIPELINE_OVERLAP", "1");
        let mut ocfg = cfg.clone();
        ocfg.pipeline = true;
        ocfg.pipeline_proc = true;
        ocfg.pipeline_socket = "unix".to_string();
        ocfg.pipeline_workers = pw;
        ocfg.pipeline_overlap = true;
        let mut hit_rate = 0.0f64;
        let mut stall_ms = 0.0f64;
        let mut fleet_fwd = 0.0f64;
        let mut frame_bytes = 0.0f64;
        let mut lookup_rtt = 0.0f64;
        let mut publish_us = 0.0f64;
        let mut apply_p50 = 0.0f64;
        let mut apply_p99 = 0.0f64;
        let mut wire = WireStats::default();
        bench.run_throughput(
            &format!("pipeline/socket-w{pw}-overlap/mlp"),
            0.0,
            steps as f64,
            || {
                let mut p =
                    PipelineTrainer::with_manifest(&ocfg, &manifest).expect("overlap pipeline");
                black_box(p.run().expect("overlap pipeline run"));
                hit_rate = p.cache_stats().hit_rate();
                stall_ms = p.eval_stall_ms() as f64;
                fleet_fwd = p.budget.inference_forwards as f64;
                frame_bytes = p.frame_bytes() as f64;
                let n = p.recorder.steps.len().max(1) as f64;
                lookup_rtt =
                    p.recorder.steps.iter().map(|s| s.lookup_rtt_us as f64).sum::<f64>() / n;
                publish_us =
                    p.recorder.steps.iter().map(|s| s.publish_us as f64).sum::<f64>() / n;
                (apply_p50, apply_p99) = p.recorder.apply_latency_us();
                wire = p.wire_stats();
            },
        );
        bench.annotate_last("inference_workers", pw as f64);
        bench.annotate_last("cache_hit_rate", hit_rate);
        bench.annotate_last("eval_stall_ms", stall_ms);
        bench.annotate_last("inference_forwards", fleet_fwd);
        bench.annotate_last("frame_bytes_per_step", frame_bytes / steps as f64);
        bench.annotate_last("lookup_rtt_us_mean", lookup_rtt);
        bench.annotate_last("publish_us_mean", publish_us);
        bench.annotate_last("sel_to_apply_p50_us", apply_p50);
        bench.annotate_last("sel_to_apply_p99_us", apply_p99);
        annotate_wire(&mut bench, &wire, steps);
        std::env::remove_var("OBFTF_PIPELINE_OVERLAP");
    }

    // elastic resharding row: the socket fleet starting at two workers
    // with one mid-run join (`pipeline_join`) and one permanent leave
    // (`--fail-after` injection with a zero restart budget → the dead
    // worker is retired above the floor) — steps/s *through* both
    // ownership transitions, with the reshard count annotated so the
    // row fails loudly if either transition stops happening
    {
        std::env::set_var("OBFTF_PIPELINE_SOCKET", "unix");
        std::env::set_var("OBFTF_PIPELINE_WORKERS", "2");
        std::env::set_var("OBFTF_PIPELINE_RESTART_LIMIT", "0");
        // the victim dies a frame-count proportional to the run length
        // in, so the leave lands mid-run at smoke and full sizes alike
        std::env::set_var("OBFTF_PROC_FAIL_AFTER", format!("1:{}", steps.max(8)));
        let mut rcfg = cfg.clone();
        rcfg.pipeline = true;
        rcfg.pipeline_proc = true;
        rcfg.pipeline_socket = "unix".to_string();
        rcfg.pipeline_workers = 2;
        rcfg.pipeline_join = format!("{}", (steps / 2).max(1));
        let mut hit_rate = 0.0f64;
        let mut fleet_fwd = 0.0f64;
        let mut frame_bytes = 0.0f64;
        let mut reshards = 0.0f64;
        let mut n_workers = 0.0f64;
        let mut wire = WireStats::default();
        bench.run_throughput("pipeline/socket-reshard/mlp", 0.0, steps as f64, || {
            let mut p =
                PipelineTrainer::with_manifest(&rcfg, &manifest).expect("reshard pipeline");
            black_box(p.run().expect("reshard pipeline run"));
            hit_rate = p.cache_stats().hit_rate();
            fleet_fwd = p.budget.inference_forwards as f64;
            frame_bytes = p.frame_bytes() as f64;
            reshards = p.reshards() as f64;
            n_workers =
                p.recorder.steps.last().map(|s| s.n_workers as f64).unwrap_or(0.0);
            wire = p.wire_stats();
        });
        bench.annotate_last("inference_workers", 2.0);
        bench.annotate_last("cache_hit_rate", hit_rate);
        bench.annotate_last("inference_forwards", fleet_fwd);
        bench.annotate_last("frame_bytes_per_step", frame_bytes / steps as f64);
        bench.annotate_last("reshards", reshards);
        bench.annotate_last("n_workers_final", n_workers);
        annotate_wire(&mut bench, &wire, steps);
        std::env::remove_var("OBFTF_PROC_FAIL_AFTER");
        std::env::remove_var("OBFTF_PIPELINE_RESTART_LIMIT");
    }
    std::env::remove_var("OBFTF_PIPELINE_SOCKET");
    std::env::set_var("OBFTF_PIPELINE_WORKERS", workers.to_string());

    bench
        .finish("staged pipeline vs serial streaming", "BENCH_pipeline.json")
        .unwrap();
}

fn main() {
    let pipeline_mode = std::env::var("OBFTF_BENCH_PIPELINE")
        .map(|v| matches!(v.trim(), "1" | "true" | "yes" | "on"))
        .unwrap_or(false);
    if pipeline_mode {
        pipeline_bench();
        return;
    }
    let mut bench = Bench::new();
    let mut rng = Rng::seed_from(0x5e1ec7);

    for &n in &[128usize, 256, 512, 1024] {
        let losses: Vec<f32> =
            (0..n).map(|_| (rng.normal() * 0.8).exp() as f32).collect();
        let valid = vec![1.0f32; n];
        for &ratio in &[0.1f64, 0.25, 0.5] {
            let b = budget_for(ratio, n);
            for m in Method::ALL {
                // cap the expensive exact solver to realistic batch sizes
                if m == Method::Obftf && n > 512 {
                    continue;
                }
                let mut sampler = m.build(1.0);
                let mut r = Rng::seed_from(7);
                bench.run(&format!("select/{}/n{}/b{}", m.as_str(), n, b), || {
                    black_box(sampler.select(&losses, &valid, b, &mut r));
                });
            }
        }
    }
    bench.finish("selection policies", "BENCH_selection.json").unwrap();
}
