//! `sel-micro` (DESIGN.md §4): selection-policy latency vs batch size
//! and budget. The L3 perf target: OBFTF's solver must cost less than
//! one fwd_loss execution at n = 128 (see EXPERIMENTS.md §Perf).
//!
//! CI smoke: set `OBFTF_BENCH_BUDGET_MS` / `OBFTF_BENCH_MAX_ITERS` for
//! a tiny run and `OBFTF_BENCH_JSON` to capture the summary artifact.

use obftf::data::rng::Rng;
use obftf::sampling::{budget_for, Method};
use obftf::util::benchkit::{black_box, Bench};

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::seed_from(0x5e1ec7);

    for &n in &[128usize, 256, 512, 1024] {
        let losses: Vec<f32> =
            (0..n).map(|_| (rng.normal() * 0.8).exp() as f32).collect();
        let valid = vec![1.0f32; n];
        for &ratio in &[0.1f64, 0.25, 0.5] {
            let b = budget_for(ratio, n);
            for m in Method::ALL {
                // cap the expensive exact solver to realistic batch sizes
                if m == Method::Obftf && n > 512 {
                    continue;
                }
                let mut sampler = m.build(1.0);
                let mut r = Rng::seed_from(7);
                bench.run(&format!("select/{}/n{}/b{}", m.as_str(), n, b), || {
                    black_box(sampler.select(&losses, &valid, b, &mut r));
                });
            }
        }
    }
    bench.finish("selection policies", "BENCH_selection.json").unwrap();
}
