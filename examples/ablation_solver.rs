//! Solver ablation (DESIGN.md `abl-solver`): objective quality and
//! latency of the subset-approximation solver stack — exact B&B vs
//! ε-DP vs Frank–Wolfe vs the OBFTF-prox heuristic — across loss
//! distributions and budgets.
//!
//! This justifies the default (B&B with node budget) and quantifies
//! what the paper's "future work" fast path (FW) gives up.
//!
//! Run:  cargo run --release --example ablation_solver

use std::time::Instant;

use obftf::data::rng::Rng;
use obftf::solver::bnb::BranchBound;
use obftf::solver::dp::DpApprox;
use obftf::solver::frank_wolfe::FrankWolfe;
use obftf::solver::{local_swap, SubsetProblem, SubsetSolver};

fn losses(dist: &str, n: usize, rng: &mut Rng) -> Vec<f32> {
    match dist {
        "uniform" => (0..n).map(|_| rng.uniform() as f32).collect(),
        "lognormal" => (0..n).map(|_| (rng.normal() * 0.8).exp() as f32).collect(),
        "bimodal" => (0..n)
            .map(|_| {
                if rng.bernoulli(0.8) {
                    0.2 + 0.1 * rng.normal().abs() as f32
                } else {
                    3.0 + rng.normal().abs() as f32
                }
            })
            .collect(),
        "outlier" => {
            let mut v: Vec<f32> = (0..n).map(|_| 1.0 + 0.2 * rng.normal() as f32).collect();
            for _ in 0..(n / 50).max(1) {
                let i = rng.below(n);
                v[i] = 100.0;
            }
            v
        }
        _ => unreachable!(),
    }
}

struct ProxSolver;

impl SubsetSolver for ProxSolver {
    fn solve(&self, p: &SubsetProblem) -> obftf::solver::Selection {
        // strided pick over sorted losses (the appendix heuristic),
        // expressed via local_swap with 0 passes for objective scoring
        let n = p.losses.len();
        let b = p.budget;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &c| p.losses[c].partial_cmp(&p.losses[a]).unwrap());
        let stride = n as f64 / (b + 1) as f64;
        let idx: Vec<usize> = (1..=b)
            .map(|i| order[((i as f64 * stride).floor() as usize).min(n - 1)])
            .collect();
        local_swap(p, idx, 0)
    }

    fn name(&self) -> &'static str {
        "prox"
    }
}

fn main() {
    let solvers: Vec<Box<dyn SubsetSolver>> = vec![
        Box::new(BranchBound::default()),
        Box::new(DpApprox::default()),
        Box::new(FrankWolfe::default()),
        Box::new(ProxSolver),
    ];
    let trials = 40;

    println!("== solver ablation: |selected mean − target| and latency ==");
    println!(
        "{:<10} {:>5} {:>4}  {:>12} {:>12} {:>12}  {:>10}",
        "dist", "n", "b", "mean obj", "max obj", "vs bnb", "µs/solve"
    );
    for dist in ["uniform", "lognormal", "bimodal", "outlier"] {
        for (n, b) in [(128usize, 32usize), (128, 64), (512, 128)] {
            // precompute instances so every solver sees identical problems
            let mut rng = Rng::seed_from(0xab1a + n as u64);
            let instances: Vec<(Vec<f32>, f64)> = (0..trials)
                .map(|_| {
                    let ls = losses(dist, n, &mut rng);
                    let mean =
                        ls.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
                    (ls, mean)
                })
                .collect();
            let mut bnb_mean = None;
            for s in &solvers {
                let mut objs = Vec::with_capacity(trials);
                let t0 = Instant::now();
                for (ls, target) in &instances {
                    let p = SubsetProblem::new(ls, b, *target).unwrap();
                    objs.push(s.solve(&p).objective);
                }
                let per_us = t0.elapsed().as_secs_f64() / trials as f64 * 1e6;
                let mean = objs.iter().sum::<f64>() / trials as f64;
                let max = objs.iter().cloned().fold(0.0f64, f64::max);
                if s.name() == "bnb" {
                    bnb_mean = Some(mean);
                }
                let vs = match bnb_mean {
                    Some(bm) if bm > 1e-15 => format!("{:>11.1}x", mean / bm),
                    _ => format!("{:>12}", "-"),
                };
                println!(
                    "{:<10} {:>5} {:>4}  {:>12.2e} {:>12.2e} {}  {:>10.1}",
                    dist, n, b, mean, max, vs, per_us
                );
                println!(
                    "ROW abl-solver dist={dist} n={n} b={b} solver={} mean_obj={mean:.3e} max_obj={max:.3e} us={per_us:.1}",
                    s.name()
                );
            }
        }
    }
    println!("\nbnb = exact (node-budgeted); dp = ε-approx grid; frank_wolfe = relaxation+swaps; prox = paper appendix heuristic");
}
