//! Fig 1 — linear regression, normalized testing loss vs sampling rate
//! (paper §4.1), clean and outlier-contaminated variants.
//!
//! Paper setup: y = 2x + 1 + U(-5,5), 1000 train / 10000 test; outlier
//! variant adds U(-20,20) to 20 training points. Reported value is the
//! test loss normalized by the full-training (ratio=1) loss, so 1.0 ==
//! "as good as training on everything".
//!
//! Run:  cargo run --release --example fig1_regression [-- --full]
//! `--full` uses the paper's 10000-point test set and a denser ratio
//! grid; the default is a fast profile with the same shape.

use anyhow::Result;

use obftf::config::TrainConfig;
use obftf::experiments::{dump_rows, full_training_loss, render_table, sweep};
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir())?;

    let methods = [
        Method::Uniform,
        Method::SelectiveBackprop,
        Method::MinK,
        Method::Obftf,
        Method::ObftfProx,
    ];
    // paper: clean sweep ≤ 0.15, outlier sweep 0.01..0.5
    let (clean_ratios, outlier_ratios): (Vec<f64>, Vec<f64>) = if full {
        (
            vec![0.01, 0.02, 0.05, 0.10, 0.15],
            vec![0.01, 0.05, 0.10, 0.15, 0.25, 0.35, 0.50],
        )
    } else {
        (vec![0.02, 0.05, 0.10, 0.15], vec![0.05, 0.15, 0.30, 0.50])
    };

    for (dataset, ratios) in [
        ("regression", &clean_ratios),
        ("regression_outliers", &outlier_ratios),
    ] {
        let base = TrainConfig {
            model: "linreg".into(),
            dataset: Some(dataset.into()),
            epochs: if full { 60 } else { 30 },
            lr: 0.01,
            seed: 1,
            eval_every: 0,
            n_test: Some(if full { 10000 } else { 2000 }),
            ..Default::default()
        };
        eprintln!("fig1 [{dataset}]: full-training baseline...");
        let baseline = full_training_loss(&base, &manifest)?;
        eprintln!("fig1 [{dataset}]: baseline loss {baseline:.4}; sweeping {} configs", methods.len() * ratios.len());
        let cells = sweep(&base, &methods, ratios, &manifest, |c| {
            eprintln!(
                "  {}/{:.2} -> loss {:.4}",
                c.method.as_str(),
                c.ratio,
                c.report.final_eval.loss
            );
        })?;
        let title = format!(
            "Fig 1 [{}]: normalized test loss (1.0 = full training, baseline {:.4})",
            dataset, baseline
        );
        println!(
            "{}",
            render_table(&title, &cells, ratios, |r| r.final_eval.loss / baseline)
        );
        print!("{}", dump_rows(&format!("fig1:{dataset}"), &cells));
    }
    Ok(())
}
