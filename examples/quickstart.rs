//! Quickstart — the end-to-end driver (DESIGN.md experiment `e2e`).
//!
//! Streams the MNIST-proxy workload through the FULL stack for 500
//! steps: data generator thread → bounded prefetch (backpressure) →
//! per-batch forward (AOT HLO via PJRT) → OBFTF selection (rust B&B
//! solver) → masked backward → live status endpoint. Logs the loss
//! curve to `quickstart_loss.csv` and prints the paper's compute
//! economics at the end.
//!
//! Run:  cargo run --release --example quickstart
//! Env:  QUICKSTART_STEPS=N (default 500), QUICKSTART_RATIO (0.25)

use anyhow::Result;

use obftf::config::TrainConfig;
use obftf::coordinator::service::{serve, StatusBoard};
use obftf::coordinator::StreamingTrainer;
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn main() -> Result<()> {
    let steps: usize = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);
    let ratio: f64 = std::env::var("QUICKSTART_RATIO")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);

    let manifest = Manifest::load_or_native(&obftf::artifacts_dir())?;
    let cfg = TrainConfig {
        model: "mlp".into(),
        method: Method::Obftf,
        sampling_ratio: ratio,
        epochs: 0,
        stream_steps: steps,
        lr: 0.1,
        seed: 42,
        eval_every: 10, // 10 evals across the run
        n_train: Some(8192),
        n_test: Some(2048),
        metrics_out: Some("quickstart_loss.csv".into()),
        ..Default::default()
    };

    println!("== obftf quickstart ==");
    println!(
        "model=mlp (784-256-256-10)  dataset=mnist_proxy  method=obftf  ratio={ratio}  steps={steps}"
    );

    // live status endpoint (read with: nc 127.0.0.1 <port> or obftf status)
    let board = StatusBoard::new();
    let server = serve(board.clone(), "127.0.0.1:0")?;
    println!("status endpoint: {}  (obftf status {})", server.addr, server.addr);
    board.update(|s| {
        s.model = "mlp".into();
        s.method = "obftf".into();
    });

    let mut trainer = StreamingTrainer::with_manifest(&cfg, &manifest)?;
    let t0 = std::time::Instant::now(); // construction (compile + datagen) excluded
    let report = trainer.run()?;
    let wall = t0.elapsed();

    board.update(|s| {
        s.step = report.steps;
        s.done = true;
    });

    println!("\n-- loss curve (eval every {} steps) --", steps / 10);
    for e in &report.evals {
        println!("step {:>5}  test-loss {:>8.4}  accuracy {:>6.2}%", e.step, e.loss, 100.0 * e.metric);
    }

    println!("\n-- result --");
    println!("final test loss      {:.4}", report.final_eval.loss);
    println!("final test accuracy  {:.2}%", 100.0 * report.final_eval.metric);
    println!("steps/sec            {:.1}", report.steps as f64 / wall.as_secs_f64());
    println!("latency              {}", report.latency_summary);
    println!(
        "producer stalls      {:.1} ms total (backpressure engaged = ingestion outpaced training)",
        trainer.producer_blocked_ns() as f64 / 1e6
    );

    println!("\n-- ten forward, one backward economics --");
    println!("forward examples     {}", report.forward_examples);
    println!("backward examples    {}", report.backward_examples);
    println!("realized ratio       {:.3}", report.realized_ratio);
    println!(
        "training cost saved  {:.1}% (vs full backward, bwd≈2×fwd)",
        100.0 * report.saved_fraction
    );
    println!("\nloss curve written to quickstart_loss.csv(.evals.csv)");
    Ok(())
}
