//! Fig 2 — MNIST(-proxy) classification accuracy vs sampling rate
//! (paper §4.2).
//!
//! Paper setup: 784-256-256-10 MLP, batch 128, lr 0.1, ratios
//! {0.1, 0.25, 0.5}; the claim to reproduce: OBFTF wins at small
//! ratios, the gap closes at 0.5, and OBFTF@0.25 ≳ others@0.5.
//!
//! Run:  cargo run --release --example fig2_mnist [-- --full]

use anyhow::Result;

use obftf::config::TrainConfig;
use obftf::experiments::{dump_rows, render_table, sweep};
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir())?;

    let methods = [
        Method::Uniform,
        Method::SelectiveBackprop,
        Method::MinK,
        Method::Obftf,
        Method::ObftfProx,
    ];
    let ratios = [0.1, 0.25, 0.5];

    let base = TrainConfig {
        model: "mlp".into(),
        dataset: Some("mnist_proxy".into()),
        epochs: if full { 12 } else { 5 },
        lr: 0.1,
        seed: 2,
        eval_every: 0,
        n_train: Some(if full { 8192 } else { 4096 }),
        n_test: Some(2048),
        // a dash of label noise gives the proxy MNIST's hard-example tail
        label_noise: 0.05,
        ..Default::default()
    };

    eprintln!(
        "fig2: sweeping {} configs ({} epochs each)...",
        methods.len() * ratios.len(),
        base.epochs
    );
    let cells = sweep(&base, &methods, &ratios, &manifest, |c| {
        eprintln!(
            "  {}/{:.2} -> acc {:.4}",
            c.method.as_str(),
            c.ratio,
            c.report.final_eval.metric
        );
    })?;

    println!(
        "{}",
        render_table(
            "Fig 2 [mnist_proxy]: test accuracy",
            &cells,
            &ratios,
            |r| r.final_eval.metric
        )
    );
    print!("{}", dump_rows("fig2:mnist_proxy", &cells));

    // the paper's headline sentence: OBFTF@0.25 vs everyone@0.5
    let acc = |m: Method, r: f64| {
        cells
            .iter()
            .find(|c| c.method == m && (c.ratio - r).abs() < 1e-9)
            .map(|c| c.report.final_eval.metric)
            .unwrap_or(f64::NAN)
    };
    println!("\nOBFTF@0.25 = {:.4}", acc(Method::Obftf, 0.25));
    for m in [Method::Uniform, Method::SelectiveBackprop, Method::MinK] {
        println!("{:<18}@0.50 = {:.4}", m.as_str(), acc(m, 0.5));
    }
    Ok(())
}
