//! Table 3 — ImageNet(-proxy) val accuracy: {uniform, max-prob, ours} ×
//! ratios {0.10, 0.15, 0.20, 0.25, 0.30, 0.45} × {ResNet50-role CNN,
//! MobileNetV2-role CNN-lite} (paper §4.3).
//!
//! The claim to reproduce: OBFTF ≥ uniform everywhere (margin largest at
//! small ratios, shrinking toward 0.45), and max-prob *collapses* — the
//! high-loss tail (label noise) monopolizes its backward budget.
//!
//! Runs **hermetically**: on a fresh checkout (no `artifacts/`) the
//! synthesized native manifest carries the cnn / cnn_lite conv chains
//! and the native backend executes them through the blocked conv
//! kernels (`runtime/kernels/conv`). `tests/table3_hermetic.rs` pins a
//! tiny-budget version of this grid in CI.
//!
//! Run:  cargo run --release --example table3_imagenet [-- --full]

use anyhow::Result;

use obftf::config::TrainConfig;
use obftf::experiments::{dump_rows, render_table, sweep};
use obftf::runtime::Manifest;
use obftf::sampling::Method;

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let manifest = Manifest::load_or_native(&obftf::artifacts_dir())?;

    // "Ours" in the paper is Eq. 6; we report both the solver-backed
    // variant (obftf) and the appendix's production approximation
    // (obftf_prox) — the latter is what scales to the paper's batch 4096.
    let methods = [Method::Uniform, Method::MaxProb, Method::Obftf, Method::ObftfProx];
    let ratios: Vec<f64> = if full {
        vec![0.10, 0.15, 0.20, 0.25, 0.30, 0.45]
    } else {
        vec![0.10, 0.20, 0.45]
    };

    for model in ["cnn", "cnn_lite"] {
        let base = TrainConfig {
            model: model.into(),
            dataset: Some("imagenet_proxy".into()),
            epochs: if full { 8 } else { 4 },
            // per-model lr found by the ratio=1 calibration sweep
            // (EXPERIMENTS.md tab3 notes): the lite model needs a hotter
            // schedule, matching the paper's per-model training setups
            lr: if model == "cnn" { 0.1 } else { 0.3 },
            seed: 3,
            eval_every: 0,
            n_train: Some(if full { 16384 } else { 4096 }),
            n_test: Some(if full { 4096 } else { 1024 }),
            // ImageNet's label noise / hard-tail is what breaks max-prob
            label_noise: 0.05,
            ..Default::default()
        };
        eprintln!(
            "table3 [{model}]: sweeping {} configs ({} epochs each)...",
            methods.len() * ratios.len(),
            base.epochs
        );
        let cells = match sweep(&base, &methods, &ratios, &manifest, |c| {
            eprintln!(
                "  {}/{:.2} -> acc {:.4}",
                c.method.as_str(),
                c.ratio,
                c.report.final_eval.metric
            );
        }) {
            Ok(cells) => cells,
            Err(e) => {
                // only reachable against an artifact manifest whose
                // conv entries lack native executables and the pjrt
                // feature is off
                eprintln!("table3 [{model}]: skipped — {e:#}");
                continue;
            }
        };
        let role = if model == "cnn" { "ResNet50-role" } else { "MobileNetV2-role" };
        println!(
            "{}",
            render_table(
                &format!("Table 3 [{model} = {role}]: val accuracy"),
                &cells,
                &ratios,
                |r| r.final_eval.metric
            )
        );
        print!("{}", dump_rows(&format!("table3:{model}"), &cells));
    }
    Ok(())
}
