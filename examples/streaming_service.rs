//! Streaming service — the production deployment shape the paper's
//! introduction motivates: a continuously-fed training subsystem with
//! concept drift, bounded-queue backpressure, live status endpoint and
//! periodic checkpoints.
//!
//! Run:  cargo run --release --example streaming_service
//! Then: obftf status 127.0.0.1:7878   (or nc 127.0.0.1 7878)
//! Env:  SERVICE_STEPS (default 300), SERVICE_ADDR (127.0.0.1:7878)

use anyhow::Result;

use obftf::config::TrainConfig;
use obftf::coordinator::service::{serve, StatusBoard};
use obftf::coordinator::StreamingTrainer;
use obftf::runtime::Manifest;
use obftf::sampling::Method;
use obftf::testkit::TempDir;

fn main() -> Result<()> {
    let steps: usize = std::env::var("SERVICE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let addr =
        std::env::var("SERVICE_ADDR").unwrap_or_else(|_| "127.0.0.1:7878".to_string());

    let manifest = Manifest::load_or_native(&obftf::artifacts_dir())?;
    let ckdir = TempDir::new("service")?;
    let cfg = TrainConfig {
        model: "mlp".into(),
        method: Method::Obftf,
        sampling_ratio: 0.2,
        epochs: 0,
        stream_steps: steps,
        lr: 0.1,
        seed: 77,
        eval_every: 6,
        drift: 0.3, // production streams shift under you
        prefetch_depth: 4,
        n_train: Some(8192),
        n_test: Some(1024),
        checkpoint: Some(ckdir.file("stream.ck").to_string_lossy().to_string()),
        ..Default::default()
    };

    let board = StatusBoard::new();
    let server = serve(board.clone(), &addr)?;
    println!("== obftf streaming service ==");
    println!("status endpoint: {}  (try: obftf status {})", server.addr, server.addr);
    println!("drift=0.3  ratio=0.2  steps={steps}");
    board.update(|s| {
        s.model = "mlp".into();
        s.method = "obftf".into();
    });

    // Run in chunks so the status board gets live updates mid-run.
    let mut trainer = StreamingTrainer::with_manifest(&cfg, &manifest)?;
    let report = {
        // StreamingTrainer::run handles eval cadence; we poll the board
        // from a watcher thread to demonstrate liveness.
        let watcher_board = board.clone();
        let t0 = std::time::Instant::now();
        let watcher = std::thread::spawn(move || {
            // simulate an operator polling the endpoint
            for _ in 0..3 {
                std::thread::sleep(std::time::Duration::from_millis(200));
                let s = watcher_board.snapshot();
                eprintln!("[watcher] step={} sel_loss={:.3}", s.step, s.sel_loss);
            }
        });
        let report = trainer.run_with_board(&board)?;
        watcher.join().ok();
        eprintln!("run took {:.1}s", t0.elapsed().as_secs_f64());
        report
    };

    board.update(|s| {
        s.done = true;
        s.step = report.steps;
    });

    println!("\n-- final --");
    println!("test loss {:.4}  accuracy {:.2}%", report.final_eval.loss, 100.0 * report.final_eval.metric);
    println!("steps/sec {:.1}", report.steps_per_sec);
    println!(
        "backpressure: producer blocked {:.1} ms total",
        trainer.producer_blocked_ns() as f64 / 1e6
    );
    println!("checkpoint resumable at {:?}", cfg.checkpoint.as_ref().unwrap());
    let status = obftf::coordinator::service::read_status(&server.addr.to_string())?;
    println!("status endpoint final answer: step={} done={}", status.step, status.done);
    Ok(())
}
